//! Service metrics: lock-free counters and log-bucketed latency
//! histograms (an HdrHistogram-flavoured fixed layout), plus an
//! iterable name→value registry that feeds every sink — the human
//! `render()` text, the Prometheus exposition endpoint, and the
//! windowed delta snapshots (`crate::obs`) — from one source of truth.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Monotonic counter.
#[derive(Debug, Default)]
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    pub fn new() -> Self {
        Counter::default()
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Last-value gauge (epoch numbers, live worker counts...).
#[derive(Debug, Default)]
pub struct Gauge {
    v: AtomicU64,
}

impl Gauge {
    pub fn new() -> Self {
        Gauge::default()
    }

    #[inline]
    pub fn set(&self, v: u64) {
        self.v.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Log₂-bucketed histogram for nanosecond latencies.
///
/// Buckets: `[2^i, 2^{i+1})` for i in 0..=63; recording is one atomic
/// add, quantiles are reconstructed from bucket midpoints (≤ 2× bucket
/// resolution error — plenty for service dashboards).
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            buckets: (0..64).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    /// Record a nanosecond value.
    pub fn record(&self, ns: u64) {
        let idx = 63 - ns.max(1).leading_zeros() as usize;
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(ns, Ordering::Relaxed);
        self.max.fetch_max(ns, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of recorded values in ns.
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Mean in ns (0 when empty).
    pub fn mean(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    /// Approximate quantile (0.0..=1.0) from bucket midpoints.
    pub fn quantile(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((total as f64) * q).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return bucket_midpoint(i);
            }
        }
        self.max()
    }

    /// Point-in-time copy of the bucket vector (the windowed-quantile
    /// input: two snapshots diffed give the distribution of *only* the
    /// interval between them).
    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            buckets: self
                .buckets
                .iter()
                .map(|b| b.load(Ordering::Relaxed))
                .collect(),
            count: self.count(),
            sum: self.sum.load(Ordering::Relaxed),
        }
    }

    /// p50/p95/p99/max one-liner for logs.
    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.0}ns p50={}ns p95={}ns p99={}ns max={}ns",
            self.count(),
            self.mean(),
            self.quantile(0.50),
            self.quantile(0.95),
            self.quantile(0.99),
            self.max()
        )
    }
}

/// Bucket midpoint: 1.5 × 2^i.
fn bucket_midpoint(i: usize) -> u64 {
    (1u64 << i) + (1u64 << i) / 2
}

/// A point-in-time copy of a [`Histogram`]'s state. Two snapshots
/// taken over an interval subtract ([`HistogramSnapshot::delta`]) into
/// the distribution of just that window — the windowed p99 that the
/// rebalancer and autoscaling act on, immune to lifetime-total inertia.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct HistogramSnapshot {
    pub buckets: Vec<u64>,
    pub count: u64,
    pub sum: u64,
}

impl HistogramSnapshot {
    /// `self - earlier`, element-wise and saturating (a snapshot pair
    /// crossing a process restart degrades to the newer snapshot
    /// rather than underflowing).
    pub fn delta(&self, earlier: &HistogramSnapshot) -> HistogramSnapshot {
        let buckets = self
            .buckets
            .iter()
            .enumerate()
            .map(|(i, b)| {
                b.saturating_sub(earlier.buckets.get(i).copied().unwrap_or(0))
            })
            .collect();
        HistogramSnapshot {
            buckets,
            count: self.count.saturating_sub(earlier.count),
            sum: self.sum.saturating_sub(earlier.sum),
        }
    }

    /// Approximate quantile from bucket midpoints (0 when empty).
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((self.count as f64) * q).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b;
            if seen >= target {
                return bucket_midpoint(i);
            }
        }
        0
    }

    /// Mean in ns (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }
}

/// A metric's current value, borrowed from its instrument. Histograms
/// are borrowed whole so sinks can choose their own decomposition
/// (quantile summaries, snapshots, plain counts).
#[derive(Debug)]
pub enum MetricValue<'a> {
    Counter(u64),
    Gauge(u64),
    Histogram(&'a Histogram),
}

/// One registry row: a stable name, a help string (the field's doc
/// comment), and the live value.
#[derive(Debug)]
pub struct Metric<'a> {
    pub name: &'static str,
    pub help: &'static str,
    pub value: MetricValue<'a>,
}

/// Anything that can appear as a registry row value.
pub trait Instrument {
    fn metric_value(&self) -> MetricValue<'_>;
}

impl Instrument for Counter {
    fn metric_value(&self) -> MetricValue<'_> {
        MetricValue::Counter(self.get())
    }
}

impl Instrument for Gauge {
    fn metric_value(&self) -> MetricValue<'_> {
        MetricValue::Gauge(self.get())
    }
}

impl Instrument for Histogram {
    fn metric_value(&self) -> MetricValue<'_> {
        MetricValue::Histogram(self)
    }
}

/// Declares a metrics bundle struct *and* its registry in one place,
/// so a field can never exist without a registry row (and therefore
/// can never silently skip a sink): the field's doc comment becomes
/// the row's help text, its name the row's name.
macro_rules! service_metrics {
    (
        $(#[doc = $sdoc:expr])*
        pub struct $name:ident {
            $(
                $(#[doc = $help:expr])+
                pub $field:ident: $ty:ident,
            )+
        }
    ) => {
        $(#[doc = $sdoc])*
        #[derive(Debug, Default)]
        pub struct $name {
            $(
                $(#[doc = $help])+
                pub $field: $ty,
            )+
        }

        impl $name {
            /// Every metric as a name→value row, in declaration order.
            /// Generated alongside the struct: complete by construction.
            pub fn registry(&self) -> Vec<Metric<'_>> {
                vec![
                    $(
                        Metric {
                            name: stringify!($field),
                            help: concat!($($help),+).trim_start(),
                            value: Instrument::metric_value(&self.$field),
                        },
                    )+
                ]
            }
        }
    };
}

service_metrics! {
    /// Shared metrics bundle for the coordinator service.
    pub struct ServiceMetrics {
        /// Samples accepted into the service.
        pub samples_in: Counter,
        /// Verdicts emitted.
        pub verdicts_out: Counter,
        /// Outliers flagged.
        pub outliers: Counter,
        /// XLA chunk executions.
        pub chunks_executed: Counter,
        /// Samples processed through the scalar fallback path (partial
        /// chunks at flush).
        pub scalar_fallback: Counter,
        /// Times a submit blocked on a full worker queue (backpressure).
        pub backpressure_events: Counter,
        /// Streams restored from a checkpoint on resume (failover).
        pub stream_restores: Counter,
        /// Re-fed samples dropped because a restored snapshot already
        /// covered them (the at-least-once replay window).
        pub replay_skipped: Counter,
        /// Streams evicted by the idle-stream policy (engine state and
        /// checkpoints — in-memory and durable — dropped together).
        pub stream_evictions: Counter,
        /// Shard migrations completed (one per seal → adopt handoff).
        pub migrations: Counter,
        /// Virtual shards moved across all migrations.
        pub shards_moved: Counter,
        /// Streams handed between workers inside migrations (snapshot →
        /// codec → restore).
        pub streams_migrated: Counter,
        /// Samples that reached a worker no longer owning their shard and
        /// were forwarded back for re-routing (stale routing snapshots
        /// during a migration — re-processed, never lost).
        pub stray_reroutes: Counter,
        /// Samples dropped by the per-stream watermark guard (at or below
        /// the last ingested seq: duplicates, or strays from a submitter
        /// that stalled across a whole migration). Protects the order-
        /// dependent recurrence from out-of-order ingestion.
        pub stale_drops: Counter,
        /// Worker threads that died by panic (guarded by `catch_unwind`;
        /// the panic surfaces as that worker's error at drain).
        pub worker_panics: Counter,
        /// Submits that observed a sender table stamped for an older
        /// routing epoch (the microseconds-wide install window between a
        /// shard-table swap and its sender-table restamp).
        pub route_epoch_misses: Counter,
        /// Data-ring pushes that found the SPSC ring full and entered the
        /// counted backpressure spin (also counted in `backpressure`).
        pub ring_full_events: Counter,
        /// Previously-parked strays re-attempted by a later drain (stuck
        /// strays are observable here rather than silently retried).
        pub parked_retries: Counter,
        /// Peer connections established by this node (transport dials,
        /// both control-plane and migration traffic).
        pub peer_connects: Counter,
        /// Cluster heartbeats sent to peers.
        pub heartbeats_tx: Counter,
        /// Cluster heartbeats received from peers.
        pub heartbeats_rx: Counter,
        /// Sealed-bundle bytes shipped to peers (outbound migrations).
        pub bundle_bytes_tx: Counter,
        /// Sealed-bundle bytes received from peers (inbound migrations
        /// and pulls).
        pub bundle_bytes_rx: Counter,
        /// Samples forwarded to the owning peer instead of being
        /// processed locally (cluster routing).
        pub samples_forwarded: Counter,
        /// Transport frames rejected (bad magic/version/CRC/length or a
        /// mid-frame disconnect).
        pub frame_errors: Counter,
        /// Failovers completed: dead peers whose shards this node
        /// recovered from the shared checkpoint store.
        pub failovers: Counter,
        /// Failover claims this node lost to a racing leader (the
        /// table moved past the observed epoch; backed off cleanly).
        pub failover_races: Counter,
        /// Members installed into the roster at runtime (dynamic
        /// joins; static peers configured at boot do not count).
        pub member_joins: Counter,
        /// Members removed from the roster by a clean Leave.
        pub member_leaves: Counter,
        /// Cross-node load rebalances performed by this node (shards
        /// shed to a colder peer by the heartbeat-driven policy).
        pub node_rebalances: Counter,
        /// Parked strays dropped because the bounded park list was
        /// full (a permanently dead destination; never silent).
        pub stray_park_drops: Counter,
        /// Samples admitted into the failover-window ingest buffer.
        pub ingest_parked: Counter,
        /// Samples refused because the ingest buffer was full
        /// (all-or-nothing admission; the caller saw an error).
        pub ingest_park_full: Counter,
        /// Current shard-map epoch (bumps once per installed table).
        pub epoch: Gauge,
        /// Current cluster shard-table epoch (node-level ownership;
        /// bumps on joins, migrations between nodes, and failovers).
        pub cluster_epoch: Gauge,
        /// Peers currently considered alive by the heartbeat monitor.
        pub peers_alive: Gauge,
        /// Samples currently parked in the ingest buffer.
        pub ingest_park_depth: Gauge,
        /// 1 while the autoscale policy recommends adding a node
        /// (sustained pressure with local worker scaling exhausted).
        pub node_scale_hint: Gauge,
        /// Live worker threads (tracks `scale_to`).
        pub workers_active: Gauge,
        /// Per-sample end-to-end latency (submit → verdict).
        pub latency: Histogram,
        /// Time a sample waited in worker queues before its job was
        /// dequeued (submit → dequeue; stage 1 of the end-to-end split).
        pub queue_wait: Histogram,
        /// Time inside the engine per processed job (ingest + flush;
        /// stage 2 of the end-to-end split).
        pub engine_time: Histogram,
        /// Time spent publishing a burst of verdicts to the result
        /// channel (stage 3 of the end-to-end split).
        pub emit_time: Histogram,
        /// Per-chunk execution time (XLA engine).
        pub chunk_time: Histogram,
        /// Wall time of one whole shard migration (seal → adopt).
        pub migration_time: Histogram,
        /// Per-worker burst sizes seen by the batched submit core (how
        /// well routing+wakeup costs amortize).
        pub batch_sizes: Histogram,
        /// Lengths of the runs of consecutive same-stream samples the
        /// batched worker path coalesces (one record per run; long runs
        /// mean the per-run hoists amortize well).
        pub run_len: Histogram,
    }
}

impl ServiceMetrics {
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    /// Multi-line human-readable report, driven by the registry (every
    /// declared metric appears; nothing to keep in sync by hand).
    pub fn render(&self) -> String {
        let mut out = String::new();
        for m in self.registry() {
            match m.value {
                MetricValue::Counter(v) | MetricValue::Gauge(v) => {
                    out.push_str(&format!("{:<20}{}\n", m.name, v));
                }
                MetricValue::Histogram(h) => {
                    out.push_str(&format!("{:<20}{}\n", m.name, h.summary()));
                }
            }
        }
        out
    }
}

/// Per-virtual-shard load tracking: sample counts plus an end-to-end
/// latency histogram per shard, so the rebalancer can find hot shards
/// (by volume or by p99) without touching any worker state.
#[derive(Debug)]
pub struct ShardStat {
    /// Samples processed for streams of this shard.
    pub samples: Counter,
    /// End-to-end latency of this shard's verdicts.
    pub latency: Histogram,
}

/// One [`ShardStat`] per virtual shard, shared by every worker.
#[derive(Debug)]
pub struct ShardMetrics {
    shards: Vec<ShardStat>,
}

impl ShardMetrics {
    pub fn new(virtual_shards: u32) -> Arc<Self> {
        Arc::new(ShardMetrics {
            shards: (0..virtual_shards)
                .map(|_| ShardStat {
                    samples: Counter::new(),
                    latency: Histogram::new(),
                })
                .collect(),
        })
    }

    /// Number of virtual shards tracked.
    pub fn len(&self) -> usize {
        self.shards.len()
    }

    pub fn is_empty(&self) -> bool {
        self.shards.is_empty()
    }

    /// Stats of one shard.
    #[inline]
    pub fn shard(&self, shard: u32) -> &ShardStat {
        &self.shards[shard as usize]
    }

    /// Point-in-time sample counts per shard (the rebalancer diffs two
    /// of these to get load-since-last-check).
    pub fn sample_counts(&self) -> Vec<u64> {
        self.shards.iter().map(|s| s.samples.get()).collect()
    }

    /// Point-in-time latency snapshots per shard (diffed by
    /// `obs::ShardWindow` into windowed per-shard p99).
    pub fn latency_snapshots(&self) -> Vec<HistogramSnapshot> {
        self.shards.iter().map(|s| s.latency.snapshot()).collect()
    }

    /// The `top` hottest shards by sample count, as
    /// `(shard, samples, p99_ns)`, hottest first. Shards with zero
    /// samples are omitted. The counter is read exactly once per shard
    /// so rank and reported count cannot disagree under live load.
    pub fn hottest(&self, top: usize) -> Vec<(u32, u64, u64)> {
        let mut rows: Vec<(u32, u64, u64)> = self
            .shards
            .iter()
            .enumerate()
            .map(|(i, s)| {
                (i as u32, s.samples.get(), s.latency.quantile(0.99))
            })
            .filter(|&(_, samples, _)| samples > 0)
            .collect();
        rows.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        rows.truncate(top);
        rows
    }
}

/// Per-ensemble-member counters (shared across all worker shards: each
/// shard's `EnsembleEngine` adds into the same atomics).
#[derive(Debug)]
pub struct MemberMetrics {
    /// Display label (`"teda(m=3)"`, ...).
    pub label: String,
    /// Votes this member produced.
    pub votes: Counter,
    /// Votes that flagged an outlier.
    pub outliers: Counter,
    /// Votes that disagreed with the fused verdict.
    pub disagreements: Counter,
    /// Wall-clock ns spent inside this member's ingest/flush calls.
    pub busy_ns: Counter,
    /// Per-call ingest latency of this member (the stage-level view of
    /// `busy_ns`: where the ensemble's nanoseconds go, member by
    /// member).
    pub vote_time: Histogram,
}

/// Ensemble-wide metrics bundle: fused totals + one row per member.
#[derive(Debug)]
pub struct EnsembleMetrics {
    pub members: Vec<MemberMetrics>,
    /// Fused verdicts emitted.
    pub fused_verdicts: Counter,
    /// Fused verdicts that flagged an outlier.
    pub fused_outliers: Counter,
    /// Samples evicted at flush because their quorum never completed
    /// (a member erred or a stream ended mid-flight). Non-zero values
    /// are a warning sign: some samples were never classified.
    pub quorum_evictions: Counter,
    /// Time to fuse one quorum of votes into a verdict (combiner call
    /// only, excluding member ingest).
    pub fuse_time: Histogram,
}

impl EnsembleMetrics {
    /// One row per member label, all counters zeroed.
    pub fn new(labels: Vec<String>) -> Arc<Self> {
        Arc::new(EnsembleMetrics {
            members: labels
                .into_iter()
                .map(|label| MemberMetrics {
                    label,
                    votes: Counter::new(),
                    outliers: Counter::new(),
                    disagreements: Counter::new(),
                    busy_ns: Counter::new(),
                    vote_time: Histogram::new(),
                })
                .collect(),
            fused_verdicts: Counter::new(),
            fused_outliers: Counter::new(),
            quorum_evictions: Counter::new(),
            fuse_time: Histogram::new(),
        })
    }

    /// Multi-line human-readable report.
    pub fn render(&self) -> String {
        let mut out = format!(
            "fused_verdicts    {}\nfused_outliers    {}\nquorum_evictions  {}\n\
             fuse_time         {}\n",
            self.fused_verdicts.get(),
            self.fused_outliers.get(),
            self.quorum_evictions.get(),
            self.fuse_time.summary(),
        );
        for m in &self.members {
            let votes = m.votes.get();
            let disagree_pct = if votes == 0 {
                0.0
            } else {
                100.0 * m.disagreements.get() as f64 / votes as f64
            };
            out.push_str(&format!(
                "  {:<24} votes={} outliers={} disagree={:.1}% busy={}µs \
                 vote_p99={}ns\n",
                m.label,
                votes,
                m.outliers.get(),
                disagree_pct,
                m.busy_ns.get() / 1000,
                m.vote_time.quantile(0.99),
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_accumulates() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn histogram_quantiles_ordered() {
        let h = Histogram::new();
        for i in 1..=1000u64 {
            h.record(i * 100);
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile(0.5);
        let p95 = h.quantile(0.95);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p95 && p95 <= p99);
        assert!(h.mean() > 0.0);
        assert_eq!(h.max(), 100_000);
        // p50 within its power-of-two bucket of the true median 50_050.
        assert!(p50 >= 32_768 && p50 <= 98_304, "p50={p50}");
    }

    #[test]
    fn histogram_empty_is_zero() {
        let h = Histogram::new();
        assert_eq!(h.quantile(0.99), 0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn histogram_concurrent_recording() {
        let h = Arc::new(Histogram::new());
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let h = h.clone();
                std::thread::spawn(move || {
                    for i in 0..10_000u64 {
                        h.record(i + 1);
                    }
                })
            })
            .collect();
        for t in handles {
            t.join().unwrap();
        }
        assert_eq!(h.count(), 40_000);
    }

    #[test]
    fn histogram_snapshot_delta_isolates_the_window() {
        let h = Histogram::new();
        for _ in 0..100 {
            h.record(1_000); // old traffic: ~1µs
        }
        let before = h.snapshot();
        for _ in 0..10 {
            h.record(1_000_000); // window traffic: ~1ms
        }
        let after = h.snapshot();
        let delta = after.delta(&before);
        assert_eq!(delta.count, 10);
        // Lifetime p99 is still dominated by the 1µs mass, but the
        // windowed p99 sees only the slow interval.
        assert!(h.quantile(0.99) < 10_000);
        assert!(delta.quantile(0.99) > 500_000, "windowed p99 sees 1ms");
        assert!(delta.mean() > 500_000.0);
        // Saturating: reversed operands degrade to zero, not underflow.
        let rev = before.delta(&after);
        assert_eq!(rev.count, 0);
        assert_eq!(rev.quantile(0.99), 0);
    }

    #[test]
    fn registry_covers_every_declared_instrument() {
        // The macro emits struct and registry from one field list, so
        // the registry is complete by construction. Belt and braces:
        // count instruments in the Debug representation (which is
        // derived straight from the struct fields) and compare with
        // the registry's per-type totals.
        let m = ServiceMetrics::default();
        let debug = format!("{m:?}");
        let count = |needle: &str| debug.matches(needle).count();
        let reg = m.registry();
        let counters = reg
            .iter()
            .filter(|r| matches!(r.value, MetricValue::Counter(_)))
            .count();
        let gauges = reg
            .iter()
            .filter(|r| matches!(r.value, MetricValue::Gauge(_)))
            .count();
        let histograms = reg
            .iter()
            .filter(|r| matches!(r.value, MetricValue::Histogram(_)))
            .count();
        assert_eq!(counters, count("Counter {"), "counters in registry");
        assert_eq!(gauges, count("Gauge {"), "gauges in registry");
        assert_eq!(histograms, count("Histogram {"), "histograms in registry");
        assert_eq!(reg.len(), counters + gauges + histograms);

        // Names are unique, non-empty, and each row carries help text.
        let mut names: Vec<&str> = reg.iter().map(|r| r.name).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), reg.len(), "duplicate registry names");
        for row in &reg {
            assert!(!row.name.is_empty());
            assert!(!row.help.is_empty(), "{} has no help text", row.name);
            assert!(
                !row.help.starts_with(' '),
                "{} help keeps its doc-comment indent",
                row.name
            );
        }
    }

    #[test]
    fn render_is_registry_driven() {
        // Sink 1 (human text) must show every registry row.
        let m = ServiceMetrics::default();
        let text = m.render();
        for row in m.registry() {
            assert!(
                text.lines().any(|l| l.starts_with(row.name)),
                "render() missing {}",
                row.name
            );
        }
    }

    #[test]
    fn ensemble_metrics_render_per_member() {
        let em = EnsembleMetrics::new(vec![
            "teda(m=3)".to_string(),
            "msigma(m=3)".to_string(),
        ]);
        em.fused_verdicts.add(10);
        em.members[0].votes.add(10);
        em.members[1].votes.add(10);
        em.members[1].disagreements.add(5);
        em.members[1].vote_time.record(2_000);
        em.fuse_time.record(500);
        let s = em.render();
        assert!(s.contains("teda(m=3)"));
        assert!(s.contains("disagree=50.0%"));
        assert!(s.contains("fused_verdicts    10"));
        assert!(s.contains("fuse_time"));
        assert!(s.contains("vote_p99="));
    }

    #[test]
    fn service_metrics_render() {
        let m = ServiceMetrics::new();
        m.samples_in.add(10);
        m.latency.record(1234);
        m.queue_wait.record(200);
        m.engine_time.record(900);
        m.emit_time.record(100);
        m.epoch.set(3);
        m.workers_active.set(5);
        m.route_epoch_misses.inc();
        m.ring_full_events.add(2);
        m.parked_retries.add(4);
        m.batch_sizes.record(8);
        m.run_len.record(16);
        let s = m.render();
        assert!(s.contains("samples_in          10"));
        assert!(s.contains("latency"));
        assert!(s.contains("queue_wait"));
        assert!(s.contains("engine_time"));
        assert!(s.contains("emit_time"));
        assert!(s.contains("epoch               3"));
        assert!(s.contains("workers_active      5"));
        assert!(s.contains("migrations          0"));
        assert!(s.contains("route_epoch_misses  1"));
        assert!(s.contains("ring_full_events    2"));
        assert!(s.contains("parked_retries      4"));
        assert!(s.contains("batch_sizes"));
        assert!(s.contains("run_len"));
    }

    #[test]
    fn gauge_holds_last_value() {
        let g = Gauge::new();
        assert_eq!(g.get(), 0);
        g.set(7);
        g.set(3);
        assert_eq!(g.get(), 3);
    }

    #[test]
    fn shard_metrics_track_and_rank() {
        let sm = ShardMetrics::new(8);
        assert_eq!(sm.len(), 8);
        sm.shard(2).samples.add(100);
        sm.shard(2).latency.record(5_000);
        sm.shard(5).samples.add(40);
        sm.shard(5).latency.record(9_000);
        let counts = sm.sample_counts();
        assert_eq!(counts[2], 100);
        assert_eq!(counts[5], 40);
        let snaps = sm.latency_snapshots();
        assert_eq!(snaps.len(), 8);
        assert_eq!(snaps[2].count, 1);
        let hot = sm.hottest(10);
        assert_eq!(hot.len(), 2, "zero-sample shards omitted");
        assert_eq!(hot[0].0, 2, "hottest first");
        assert!(hot[0].2 > 0, "p99 populated");
        assert_eq!(hot[1].0, 5);
    }
}

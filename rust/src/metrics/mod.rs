//! Service metrics: lock-free counters and log-bucketed latency
//! histograms (an HdrHistogram-flavoured fixed layout), plus a registry
//! for rendering.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Monotonic counter.
#[derive(Debug, Default)]
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    pub fn new() -> Self {
        Counter::default()
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Last-value gauge (epoch numbers, live worker counts...).
#[derive(Debug, Default)]
pub struct Gauge {
    v: AtomicU64,
}

impl Gauge {
    pub fn new() -> Self {
        Gauge::default()
    }

    #[inline]
    pub fn set(&self, v: u64) {
        self.v.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Log₂-bucketed histogram for nanosecond latencies.
///
/// Buckets: `[2^i, 2^{i+1})` for i in 0..=63; recording is one atomic
/// add, quantiles are reconstructed from bucket midpoints (≤ 2× bucket
/// resolution error — plenty for service dashboards).
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            buckets: (0..64).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    /// Record a nanosecond value.
    pub fn record(&self, ns: u64) {
        let idx = 63 - ns.max(1).leading_zeros() as usize;
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(ns, Ordering::Relaxed);
        self.max.fetch_max(ns, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean in ns (0 when empty).
    pub fn mean(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    /// Approximate quantile (0.0..=1.0) from bucket midpoints.
    pub fn quantile(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((total as f64) * q).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                // Bucket midpoint: 1.5 × 2^i.
                return (1u64 << i) + (1u64 << i) / 2;
            }
        }
        self.max()
    }

    /// p50/p95/p99/max one-liner for logs.
    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.0}ns p50={}ns p95={}ns p99={}ns max={}ns",
            self.count(),
            self.mean(),
            self.quantile(0.50),
            self.quantile(0.95),
            self.quantile(0.99),
            self.max()
        )
    }
}

/// Shared metrics bundle for the coordinator service.
#[derive(Debug, Default)]
pub struct ServiceMetrics {
    /// Samples accepted into the service.
    pub samples_in: Counter,
    /// Verdicts emitted.
    pub verdicts_out: Counter,
    /// Outliers flagged.
    pub outliers: Counter,
    /// XLA chunk executions.
    pub chunks_executed: Counter,
    /// Samples processed through the scalar fallback path (partial
    /// chunks at flush).
    pub scalar_fallback: Counter,
    /// Times a submit blocked on a full worker queue (backpressure).
    pub backpressure_events: Counter,
    /// Streams restored from a checkpoint on resume (failover).
    pub stream_restores: Counter,
    /// Re-fed samples dropped because a restored snapshot already
    /// covered them (the at-least-once replay window).
    pub replay_skipped: Counter,
    /// Streams evicted by the idle-stream policy (engine state and
    /// checkpoints — in-memory and durable — dropped together).
    pub stream_evictions: Counter,
    /// Shard migrations completed (one per seal → adopt handoff).
    pub migrations: Counter,
    /// Virtual shards moved across all migrations.
    pub shards_moved: Counter,
    /// Streams handed between workers inside migrations (snapshot →
    /// codec → restore).
    pub streams_migrated: Counter,
    /// Samples that reached a worker no longer owning their shard and
    /// were forwarded back for re-routing (stale routing snapshots
    /// during a migration — re-processed, never lost).
    pub stray_reroutes: Counter,
    /// Samples dropped by the per-stream watermark guard (at or below
    /// the last ingested seq: duplicates, or strays from a submitter
    /// that stalled across a whole migration). Protects the order-
    /// dependent recurrence from out-of-order ingestion.
    pub stale_drops: Counter,
    /// Worker threads that died by panic (guarded by `catch_unwind`;
    /// the panic surfaces as that worker's error at drain).
    pub worker_panics: Counter,
    /// Submits that observed a sender table stamped for an older
    /// routing epoch (the microseconds-wide install window between a
    /// shard-table swap and its sender-table restamp).
    pub route_epoch_misses: Counter,
    /// Data-ring pushes that found the SPSC ring full and entered the
    /// counted backpressure spin (also counted in `backpressure`).
    pub ring_full_events: Counter,
    /// Previously-parked strays re-attempted by a later drain (stuck
    /// strays are observable here rather than silently retried).
    pub parked_retries: Counter,
    /// Current shard-map epoch (bumps once per installed table).
    pub epoch: Gauge,
    /// Live worker threads (tracks `scale_to`).
    pub workers_active: Gauge,
    /// Per-sample end-to-end latency (submit → verdict).
    pub latency: Histogram,
    /// Per-chunk execution time (XLA engine).
    pub chunk_time: Histogram,
    /// Wall time of one whole shard migration (seal → adopt).
    pub migration_time: Histogram,
    /// Per-worker burst sizes seen by the batched submit core (how
    /// well routing+wakeup costs amortize).
    pub batch_sizes: Histogram,
}

impl ServiceMetrics {
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    /// Multi-line human-readable report.
    pub fn render(&self) -> String {
        format!(
            "samples_in        {}\n\
             verdicts_out      {}\n\
             outliers          {}\n\
             chunks_executed   {}\n\
             scalar_fallback   {}\n\
             backpressure      {}\n\
             stream_restores   {}\n\
             replay_skipped    {}\n\
             stream_evictions  {}\n\
             migrations        {}\n\
             shards_moved      {}\n\
             streams_migrated  {}\n\
             stray_reroutes    {}\n\
             stale_drops       {}\n\
             worker_panics     {}\n\
             route_epoch_miss  {}\n\
             ring_full         {}\n\
             parked_retries    {}\n\
             epoch             {}\n\
             workers_active    {}\n\
             latency           {}\n\
             chunk_time        {}\n\
             migration_time    {}\n\
             batch_sizes       {}\n",
            self.samples_in.get(),
            self.verdicts_out.get(),
            self.outliers.get(),
            self.chunks_executed.get(),
            self.scalar_fallback.get(),
            self.backpressure_events.get(),
            self.stream_restores.get(),
            self.replay_skipped.get(),
            self.stream_evictions.get(),
            self.migrations.get(),
            self.shards_moved.get(),
            self.streams_migrated.get(),
            self.stray_reroutes.get(),
            self.stale_drops.get(),
            self.worker_panics.get(),
            self.route_epoch_misses.get(),
            self.ring_full_events.get(),
            self.parked_retries.get(),
            self.epoch.get(),
            self.workers_active.get(),
            self.latency.summary(),
            self.chunk_time.summary(),
            self.migration_time.summary(),
            self.batch_sizes.summary(),
        )
    }
}

/// Per-virtual-shard load tracking: sample counts plus an end-to-end
/// latency histogram per shard, so the rebalancer can find hot shards
/// (by volume or by p99) without touching any worker state.
#[derive(Debug)]
pub struct ShardStat {
    /// Samples processed for streams of this shard.
    pub samples: Counter,
    /// End-to-end latency of this shard's verdicts.
    pub latency: Histogram,
}

/// One [`ShardStat`] per virtual shard, shared by every worker.
#[derive(Debug)]
pub struct ShardMetrics {
    shards: Vec<ShardStat>,
}

impl ShardMetrics {
    pub fn new(virtual_shards: u32) -> Arc<Self> {
        Arc::new(ShardMetrics {
            shards: (0..virtual_shards)
                .map(|_| ShardStat {
                    samples: Counter::new(),
                    latency: Histogram::new(),
                })
                .collect(),
        })
    }

    /// Number of virtual shards tracked.
    pub fn len(&self) -> usize {
        self.shards.len()
    }

    pub fn is_empty(&self) -> bool {
        self.shards.is_empty()
    }

    /// Stats of one shard.
    #[inline]
    pub fn shard(&self, shard: u32) -> &ShardStat {
        &self.shards[shard as usize]
    }

    /// Point-in-time sample counts per shard (the rebalancer diffs two
    /// of these to get load-since-last-check).
    pub fn sample_counts(&self) -> Vec<u64> {
        self.shards.iter().map(|s| s.samples.get()).collect()
    }

    /// The `top` hottest shards by sample count, as
    /// `(shard, samples, p99_ns)`, hottest first. Shards with zero
    /// samples are omitted.
    pub fn hottest(&self, top: usize) -> Vec<(u32, u64, u64)> {
        let mut rows: Vec<(u32, u64, u64)> = self
            .shards
            .iter()
            .enumerate()
            .filter(|(_, s)| s.samples.get() > 0)
            .map(|(i, s)| {
                (i as u32, s.samples.get(), s.latency.quantile(0.99))
            })
            .collect();
        rows.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        rows.truncate(top);
        rows
    }
}

/// Per-ensemble-member counters (shared across all worker shards: each
/// shard's `EnsembleEngine` adds into the same atomics).
#[derive(Debug)]
pub struct MemberMetrics {
    /// Display label (`"teda(m=3)"`, ...).
    pub label: String,
    /// Votes this member produced.
    pub votes: Counter,
    /// Votes that flagged an outlier.
    pub outliers: Counter,
    /// Votes that disagreed with the fused verdict.
    pub disagreements: Counter,
    /// Wall-clock ns spent inside this member's ingest/flush calls.
    pub busy_ns: Counter,
}

/// Ensemble-wide metrics bundle: fused totals + one row per member.
#[derive(Debug)]
pub struct EnsembleMetrics {
    pub members: Vec<MemberMetrics>,
    /// Fused verdicts emitted.
    pub fused_verdicts: Counter,
    /// Fused verdicts that flagged an outlier.
    pub fused_outliers: Counter,
    /// Samples evicted at flush because their quorum never completed
    /// (a member erred or a stream ended mid-flight). Non-zero values
    /// are a warning sign: some samples were never classified.
    pub quorum_evictions: Counter,
}

impl EnsembleMetrics {
    /// One row per member label, all counters zeroed.
    pub fn new(labels: Vec<String>) -> Arc<Self> {
        Arc::new(EnsembleMetrics {
            members: labels
                .into_iter()
                .map(|label| MemberMetrics {
                    label,
                    votes: Counter::new(),
                    outliers: Counter::new(),
                    disagreements: Counter::new(),
                    busy_ns: Counter::new(),
                })
                .collect(),
            fused_verdicts: Counter::new(),
            fused_outliers: Counter::new(),
            quorum_evictions: Counter::new(),
        })
    }

    /// Multi-line human-readable report.
    pub fn render(&self) -> String {
        let mut out = format!(
            "fused_verdicts    {}\nfused_outliers    {}\nquorum_evictions  {}\n",
            self.fused_verdicts.get(),
            self.fused_outliers.get(),
            self.quorum_evictions.get()
        );
        for m in &self.members {
            let votes = m.votes.get();
            let disagree_pct = if votes == 0 {
                0.0
            } else {
                100.0 * m.disagreements.get() as f64 / votes as f64
            };
            out.push_str(&format!(
                "  {:<24} votes={} outliers={} disagree={:.1}% busy={}µs\n",
                m.label,
                votes,
                m.outliers.get(),
                disagree_pct,
                m.busy_ns.get() / 1000,
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_accumulates() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn histogram_quantiles_ordered() {
        let h = Histogram::new();
        for i in 1..=1000u64 {
            h.record(i * 100);
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile(0.5);
        let p95 = h.quantile(0.95);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p95 && p95 <= p99);
        assert!(h.mean() > 0.0);
        assert_eq!(h.max(), 100_000);
        // p50 within its power-of-two bucket of the true median 50_050.
        assert!(p50 >= 32_768 && p50 <= 98_304, "p50={p50}");
    }

    #[test]
    fn histogram_empty_is_zero() {
        let h = Histogram::new();
        assert_eq!(h.quantile(0.99), 0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn histogram_concurrent_recording() {
        let h = Arc::new(Histogram::new());
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let h = h.clone();
                std::thread::spawn(move || {
                    for i in 0..10_000u64 {
                        h.record(i + 1);
                    }
                })
            })
            .collect();
        for t in handles {
            t.join().unwrap();
        }
        assert_eq!(h.count(), 40_000);
    }

    #[test]
    fn ensemble_metrics_render_per_member() {
        let em = EnsembleMetrics::new(vec![
            "teda(m=3)".to_string(),
            "msigma(m=3)".to_string(),
        ]);
        em.fused_verdicts.add(10);
        em.members[0].votes.add(10);
        em.members[1].votes.add(10);
        em.members[1].disagreements.add(5);
        let s = em.render();
        assert!(s.contains("teda(m=3)"));
        assert!(s.contains("disagree=50.0%"));
        assert!(s.contains("fused_verdicts    10"));
    }

    #[test]
    fn service_metrics_render() {
        let m = ServiceMetrics::new();
        m.samples_in.add(10);
        m.latency.record(1234);
        m.epoch.set(3);
        m.workers_active.set(5);
        m.route_epoch_misses.inc();
        m.ring_full_events.add(2);
        m.parked_retries.add(4);
        m.batch_sizes.record(8);
        let s = m.render();
        assert!(s.contains("samples_in        10"));
        assert!(s.contains("latency"));
        assert!(s.contains("epoch             3"));
        assert!(s.contains("workers_active    5"));
        assert!(s.contains("migrations        0"));
        assert!(s.contains("route_epoch_miss  1"));
        assert!(s.contains("ring_full         2"));
        assert!(s.contains("parked_retries    4"));
        assert!(s.contains("batch_sizes"));
    }

    #[test]
    fn gauge_holds_last_value() {
        let g = Gauge::new();
        assert_eq!(g.get(), 0);
        g.set(7);
        g.set(3);
        assert_eq!(g.get(), 3);
    }

    #[test]
    fn shard_metrics_track_and_rank() {
        let sm = ShardMetrics::new(8);
        assert_eq!(sm.len(), 8);
        sm.shard(2).samples.add(100);
        sm.shard(2).latency.record(5_000);
        sm.shard(5).samples.add(40);
        sm.shard(5).latency.record(9_000);
        let counts = sm.sample_counts();
        assert_eq!(counts[2], 100);
        assert_eq!(counts[5], 40);
        let hot = sm.hottest(10);
        assert_eq!(hot.len(), 2, "zero-sample shards omitted");
        assert_eq!(hot[0].0, 2, "hottest first");
        assert!(hot[0].2 > 0, "p99 populated");
        assert_eq!(hot[1].0, 5);
    }
}

//! Service metrics: lock-free counters and log-bucketed latency
//! histograms (an HdrHistogram-flavoured fixed layout), plus a registry
//! for rendering.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Monotonic counter.
#[derive(Debug, Default)]
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    pub fn new() -> Self {
        Counter::default()
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Log₂-bucketed histogram for nanosecond latencies.
///
/// Buckets: `[2^i, 2^{i+1})` for i in 0..=63; recording is one atomic
/// add, quantiles are reconstructed from bucket midpoints (≤ 2× bucket
/// resolution error — plenty for service dashboards).
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            buckets: (0..64).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    /// Record a nanosecond value.
    pub fn record(&self, ns: u64) {
        let idx = 63 - ns.max(1).leading_zeros() as usize;
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(ns, Ordering::Relaxed);
        self.max.fetch_max(ns, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean in ns (0 when empty).
    pub fn mean(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    /// Approximate quantile (0.0..=1.0) from bucket midpoints.
    pub fn quantile(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((total as f64) * q).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                // Bucket midpoint: 1.5 × 2^i.
                return (1u64 << i) + (1u64 << i) / 2;
            }
        }
        self.max()
    }

    /// p50/p95/p99/max one-liner for logs.
    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.0}ns p50={}ns p95={}ns p99={}ns max={}ns",
            self.count(),
            self.mean(),
            self.quantile(0.50),
            self.quantile(0.95),
            self.quantile(0.99),
            self.max()
        )
    }
}

/// Shared metrics bundle for the coordinator service.
#[derive(Debug, Default)]
pub struct ServiceMetrics {
    /// Samples accepted into the service.
    pub samples_in: Counter,
    /// Verdicts emitted.
    pub verdicts_out: Counter,
    /// Outliers flagged.
    pub outliers: Counter,
    /// XLA chunk executions.
    pub chunks_executed: Counter,
    /// Samples processed through the scalar fallback path (partial
    /// chunks at flush).
    pub scalar_fallback: Counter,
    /// Times a submit blocked on a full worker queue (backpressure).
    pub backpressure_events: Counter,
    /// Streams restored from a checkpoint on resume (failover).
    pub stream_restores: Counter,
    /// Re-fed samples dropped because a restored snapshot already
    /// covered them (the at-least-once replay window).
    pub replay_skipped: Counter,
    /// Streams evicted by the idle-stream policy (engine state and
    /// checkpoints — in-memory and durable — dropped together).
    pub stream_evictions: Counter,
    /// Per-sample end-to-end latency (submit → verdict).
    pub latency: Histogram,
    /// Per-chunk execution time (XLA engine).
    pub chunk_time: Histogram,
}

impl ServiceMetrics {
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    /// Multi-line human-readable report.
    pub fn render(&self) -> String {
        format!(
            "samples_in        {}\n\
             verdicts_out      {}\n\
             outliers          {}\n\
             chunks_executed   {}\n\
             scalar_fallback   {}\n\
             backpressure      {}\n\
             stream_restores   {}\n\
             replay_skipped    {}\n\
             stream_evictions  {}\n\
             latency           {}\n\
             chunk_time        {}\n",
            self.samples_in.get(),
            self.verdicts_out.get(),
            self.outliers.get(),
            self.chunks_executed.get(),
            self.scalar_fallback.get(),
            self.backpressure_events.get(),
            self.stream_restores.get(),
            self.replay_skipped.get(),
            self.stream_evictions.get(),
            self.latency.summary(),
            self.chunk_time.summary(),
        )
    }
}

/// Per-ensemble-member counters (shared across all worker shards: each
/// shard's `EnsembleEngine` adds into the same atomics).
#[derive(Debug)]
pub struct MemberMetrics {
    /// Display label (`"teda(m=3)"`, ...).
    pub label: String,
    /// Votes this member produced.
    pub votes: Counter,
    /// Votes that flagged an outlier.
    pub outliers: Counter,
    /// Votes that disagreed with the fused verdict.
    pub disagreements: Counter,
    /// Wall-clock ns spent inside this member's ingest/flush calls.
    pub busy_ns: Counter,
}

/// Ensemble-wide metrics bundle: fused totals + one row per member.
#[derive(Debug)]
pub struct EnsembleMetrics {
    pub members: Vec<MemberMetrics>,
    /// Fused verdicts emitted.
    pub fused_verdicts: Counter,
    /// Fused verdicts that flagged an outlier.
    pub fused_outliers: Counter,
    /// Samples evicted at flush because their quorum never completed
    /// (a member erred or a stream ended mid-flight). Non-zero values
    /// are a warning sign: some samples were never classified.
    pub quorum_evictions: Counter,
}

impl EnsembleMetrics {
    /// One row per member label, all counters zeroed.
    pub fn new(labels: Vec<String>) -> Arc<Self> {
        Arc::new(EnsembleMetrics {
            members: labels
                .into_iter()
                .map(|label| MemberMetrics {
                    label,
                    votes: Counter::new(),
                    outliers: Counter::new(),
                    disagreements: Counter::new(),
                    busy_ns: Counter::new(),
                })
                .collect(),
            fused_verdicts: Counter::new(),
            fused_outliers: Counter::new(),
            quorum_evictions: Counter::new(),
        })
    }

    /// Multi-line human-readable report.
    pub fn render(&self) -> String {
        let mut out = format!(
            "fused_verdicts    {}\nfused_outliers    {}\nquorum_evictions  {}\n",
            self.fused_verdicts.get(),
            self.fused_outliers.get(),
            self.quorum_evictions.get()
        );
        for m in &self.members {
            let votes = m.votes.get();
            let disagree_pct = if votes == 0 {
                0.0
            } else {
                100.0 * m.disagreements.get() as f64 / votes as f64
            };
            out.push_str(&format!(
                "  {:<24} votes={} outliers={} disagree={:.1}% busy={}µs\n",
                m.label,
                votes,
                m.outliers.get(),
                disagree_pct,
                m.busy_ns.get() / 1000,
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_accumulates() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn histogram_quantiles_ordered() {
        let h = Histogram::new();
        for i in 1..=1000u64 {
            h.record(i * 100);
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile(0.5);
        let p95 = h.quantile(0.95);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p95 && p95 <= p99);
        assert!(h.mean() > 0.0);
        assert_eq!(h.max(), 100_000);
        // p50 within its power-of-two bucket of the true median 50_050.
        assert!(p50 >= 32_768 && p50 <= 98_304, "p50={p50}");
    }

    #[test]
    fn histogram_empty_is_zero() {
        let h = Histogram::new();
        assert_eq!(h.quantile(0.99), 0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn histogram_concurrent_recording() {
        let h = Arc::new(Histogram::new());
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let h = h.clone();
                std::thread::spawn(move || {
                    for i in 0..10_000u64 {
                        h.record(i + 1);
                    }
                })
            })
            .collect();
        for t in handles {
            t.join().unwrap();
        }
        assert_eq!(h.count(), 40_000);
    }

    #[test]
    fn ensemble_metrics_render_per_member() {
        let em = EnsembleMetrics::new(vec![
            "teda(m=3)".to_string(),
            "msigma(m=3)".to_string(),
        ]);
        em.fused_verdicts.add(10);
        em.members[0].votes.add(10);
        em.members[1].votes.add(10);
        em.members[1].disagreements.add(5);
        let s = em.render();
        assert!(s.contains("teda(m=3)"));
        assert!(s.contains("disagree=50.0%"));
        assert!(s.contains("fused_verdicts    10"));
    }

    #[test]
    fn service_metrics_render() {
        let m = ServiceMetrics::new();
        m.samples_in.add(10);
        m.latency.record(1234);
        let s = m.render();
        assert!(s.contains("samples_in        10"));
        assert!(s.contains("latency"));
    }
}

//! End-to-end service bench: full coordinator throughput per engine and
//! worker count (the L3 scaling study — the paper's "multiple TEDA
//! modules in parallel" argument, measured).
//!
//! Run: `cargo bench --bench e2e_service`

use teda_fpga::config::{EngineKind, ServiceConfig};
use teda_fpga::coordinator::Service;
use teda_fpga::stream::Sample;
use teda_fpga::util::benchkit::Bench;
use teda_fpga::util::prng::SplitMix64;

fn run_service(
    engine: EngineKind,
    workers: usize,
    streams: u64,
    per_stream: usize,
    iters: usize,
) -> f64 {
    let cfg = ServiceConfig {
        engine,
        workers,
        n_features: 2,
        queue_capacity: 1024,
        artifact_dir: concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts").into(),
        ..Default::default()
    };
    let total = streams as usize * per_stream;
    let mut rng = SplitMix64::new(3);
    let mut workload: Vec<Sample> = Vec::with_capacity(total);
    for seq in 0..per_stream {
        for sid in 0..streams {
            workload.push(Sample {
                stream_id: sid,
                seq: seq as u64,
                values: vec![rng.next_f64(), rng.next_f64()],
            });
        }
    }
    let report = Bench::new(format!(
        "service_{engine}_w{workers}_s{streams}"
    ))
    .iters(iters)
    .units(total as u64, "samples")
    .run(|| {
        let svc = Service::start(cfg.clone()).unwrap();
        // Submit in bursts of one round across all streams (what a
        // polling ingress naturally produces).
        for round in workload.chunks(streams as usize) {
            svc.submit_batch(round.to_vec()).unwrap();
        }
        let out = svc.finish().unwrap();
        assert_eq!(out.len(), total);
    });
    report.throughput
}

fn main() {
    let have_artifacts = std::path::Path::new(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/artifacts/manifest.json"
    ))
    .exists();

    println!("== end-to-end service throughput (samples/s) ==\n");
    println!("engine    | workers | throughput");
    println!("----------|---------|------------");
    for engine in [EngineKind::Software, EngineKind::Rtl] {
        for workers in [1usize, 2, 4] {
            let tp = run_service(engine, workers, 16, 4000, 5);
            println!("{engine:<9} | {workers:>7} | {tp:>10.0}");
        }
    }
    if have_artifacts {
        // Larger workload so the per-service PJRT compile (~0.4 s per
        // worker, overlapped with submission) amortizes to noise.
        for workers in [1usize, 2] {
            let tp = run_service(EngineKind::Xla, workers, 32, 16_000, 3);
            println!("{:<9} | {workers:>7} | {tp:>10.0}", "xla");
        }
    } else {
        eprintln!("(artifacts missing — xla rows skipped)");
    }
}

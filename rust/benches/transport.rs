//! Cluster transport costs: frame encode/decode, a loopback-TCP RPC
//! round trip, and the number the distributed design actually turns
//! on — what a seal→adopt shard migration pays when it crosses a
//! process boundary instead of a worker queue.
//!
//! Emits `BENCH_transport.json` at the repository root and appends the
//! run to the cumulative `BENCH_trend.json`.
//!
//! Run: `cargo bench --bench transport`

use std::collections::BTreeMap;
use std::net::TcpListener;
use std::sync::Arc;
use std::thread;

use teda_fpga::config::{ClusterConfig, Json, ServiceConfig, ShardingConfig};
use teda_fpga::coordinator::transport::frame::{self, Msg};
use teda_fpga::coordinator::transport::net::{PeerAddr, RpcClient};
use teda_fpga::coordinator::{ClusterNode, Service};
use teda_fpga::stream::Sample;
use teda_fpga::util::benchkit::{black_box, Bench};
use teda_fpga::util::prng::SplitMix64;

/// Frames per measured iteration for the codec rows.
const FRAMES: u64 = 10_000;
/// RPC round trips per measured iteration.
const RPCS: u64 = 500;
/// Shard moves per measured iteration for the migration rows.
const MOVES: u64 = 10;
/// Shards per move (matches a typical rebalance step).
const SHARDS_PER_MOVE: usize = 4;
/// Streams warmed up before the migration ping-pong.
const STREAMS: u64 = 16;
const WARM_SAMPLES: u64 = 200;

/// Loopback ports for the cross-node row (benches run one at a time;
/// distinct from the 1746x pair the e2e test uses).
const PORT_A: u16 = 17471;
const PORT_B: u16 = 17472;

fn num(v: f64) -> Json {
    Json::Num((v * 10.0).round() / 10.0)
}

fn row(results: &mut Vec<Json>, metric: &str, value: f64) {
    let mut row = BTreeMap::new();
    row.insert("metric".into(), Json::Str(metric.into()));
    row.insert("value".into(), num(value));
    results.push(Json::Obj(row));
}

fn sample(sid: u64, seq: u64) -> Sample {
    let mut rng = SplitMix64::new(sid.wrapping_mul(0x9E37) ^ seq);
    Sample {
        stream_id: sid,
        seq,
        values: vec![rng.uniform(-2.0, 2.0), rng.uniform(-2.0, 2.0)],
    }
}

fn svc_cfg() -> ServiceConfig {
    ServiceConfig {
        workers: 2,
        n_features: 2,
        queue_capacity: 256,
        sharding: ShardingConfig { virtual_shards: 32, ..Default::default() },
        ..Default::default()
    }
}

fn codec_rows(results: &mut Vec<Json>) {
    let batch: Vec<Sample> = (0..64).map(|i| sample(i, i * 7)).collect();
    let cases: Vec<(&str, Msg)> = vec![
        ("heartbeat", Msg::Heartbeat { node_id: 1, epoch: 3, load: 512 }),
        ("batch64", Msg::Samples { samples: batch }),
        (
            "bundle64k",
            Msg::Bundle { records: vec![vec![0x5A; 1024]; 64] },
        ),
    ];
    for (label, msg) in &cases {
        let enc = Bench::new(&format!("encode_{label}"))
            .iters(30)
            .units(FRAMES, "frames")
            .run(|| {
                for _ in 0..FRAMES {
                    black_box(frame::encode(black_box(msg)));
                }
            });
        row(results, &format!("encode_{label}_ns"), enc.ns_per_unit);
        let wire = frame::encode(msg);
        let dec = Bench::new(&format!("decode_{label}"))
            .iters(30)
            .units(FRAMES, "frames")
            .run(|| {
                for _ in 0..FRAMES {
                    black_box(frame::decode(black_box(&wire)).unwrap());
                }
            });
        row(results, &format!("decode_{label}_ns"), dec.ns_per_unit);
        println!(
            "  {label}: {} B/frame, encode {:.0} ns, decode {:.0} ns",
            wire.len(),
            enc.ns_per_unit,
            dec.ns_per_unit
        );
    }
}

fn rpc_row(results: &mut Vec<Json>) {
    // Minimal echo peer: every request gets a HelloOk back.
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind echo");
    let addr = listener.local_addr().expect("echo addr");
    let server = thread::spawn(move || {
        let (mut conn, _) = listener.accept().expect("accept");
        while let Ok(Some(_)) = frame::read_msg(&mut conn) {
            frame::write_msg(
                &mut conn,
                &Msg::HelloOk { node_id: 2, epoch: 0 },
            )
            .expect("echo reply");
        }
    });
    let client = RpcClient::new(PeerAddr::Tcp(addr.to_string()));
    let probe = Msg::Heartbeat { node_id: 1, epoch: 0, load: 0 };
    client.rpc(&probe).expect("rpc warmup");
    let rpc = Bench::new("rpc_roundtrip")
        .iters(20)
        .units(RPCS, "rpcs")
        .run(|| {
            for _ in 0..RPCS {
                black_box(client.rpc(&probe).expect("rpc"));
            }
        });
    row(results, "rpc_roundtrip_ns", rpc.ns_per_unit);
    println!("  rpc round trip: {:.0} ns", rpc.ns_per_unit);
    client.disconnect();
    server.join().expect("echo server");
}

/// Warm `STREAMS` streams into a service so sealed bundles carry real
/// state.
fn warm(submit: &mut dyn FnMut(Vec<Sample>)) {
    for seq in 0..WARM_SAMPLES {
        submit((0..STREAMS).map(|sid| sample(sid, seq)).collect());
    }
}

fn migrate_inproc_row(results: &mut Vec<Json>) -> f64 {
    let svc = Service::start(svc_cfg()).expect("start service");
    warm(&mut |burst| svc.submit_batch(burst).expect("submit"));
    // Ping-pong the same shard set between the two workers: each move
    // is a full seal → snapshot → adopt → replay cycle, all in-process.
    // Same shard set the TCP row moves (node 1's first four at epoch 0)
    // so the two rows seal identical stream populations.
    let shards: Vec<u32> = vec![0, 2, 4, 6];
    let mut dst = 1usize;
    let mig = Bench::new("migrate_inproc")
        .iters(20)
        .units(MOVES, "migrations")
        .run(|| {
            for _ in 0..MOVES {
                let moves: Vec<(u32, usize)> =
                    shards.iter().map(|&s| (s, dst)).collect();
                svc.migrate_shards(&moves).expect("migrate");
                dst = 1 - dst;
            }
        });
    row(results, "migrate_inproc_ns", mig.ns_per_unit);
    println!(
        "  in-process migration ({SHARDS_PER_MOVE} shards): {:.0} ns",
        mig.ns_per_unit
    );
    drop(svc.finish().expect("finish"));
    mig.ns_per_unit
}

fn migrate_tcp_row(results: &mut Vec<Json>) -> f64 {
    let a = format!("127.0.0.1:{PORT_A}");
    let b = format!("127.0.0.1:{PORT_B}");
    let c1 = ClusterConfig {
        node_id: 1,
        listen: Some(a.clone()),
        peers: vec![format!("2={b}")],
        heartbeat_ms: 500,
        failover_ms: 0,
        ..Default::default()
    };
    let c2 = ClusterConfig {
        node_id: 2,
        listen: Some(b),
        peers: vec![format!("1={a}")],
        heartbeat_ms: 500,
        failover_ms: 0,
        ..Default::default()
    };
    let svc1 = Arc::new(Service::start(svc_cfg()).expect("node 1 svc"));
    let svc2 = Arc::new(Service::start(svc_cfg()).expect("node 2 svc"));
    let n1 = ClusterNode::start(svc1.clone(), &c1).expect("node 1");
    let n2 = ClusterNode::start(svc2.clone(), &c2).expect("node 2");
    assert_eq!(n1.hello_peers(), 1, "node 2 must answer hello");
    let ingest = n1.handle();
    warm(&mut |burst| ingest.submit_batch(burst).expect("submit"));
    // The same ping-pong, but each move now crosses the loopback wire:
    // Table push + Expect + Seal reply hauling the bundle + barrier +
    // Adopt, all framed RPCs.
    let shards: Vec<u32> = n1
        .owned_shards()
        .into_iter()
        .take(SHARDS_PER_MOVE)
        .collect();
    let mut here = true; // whose turn it is to push
    let mig = Bench::new("migrate_tcp")
        .iters(20)
        .units(MOVES, "migrations")
        .run(|| {
            for _ in 0..MOVES {
                if here {
                    n1.migrate_to_peer(2, &shards).expect("push 1→2");
                } else {
                    n2.migrate_to_peer(1, &shards).expect("push 2→1");
                }
                here = !here;
            }
        });
    row(results, "migrate_tcp_ns", mig.ns_per_unit);
    println!(
        "  loopback-TCP migration ({SHARDS_PER_MOVE} shards): {:.0} ns",
        mig.ns_per_unit
    );
    drop(ingest);
    n1.shutdown().expect("node 1 shutdown");
    n2.shutdown().expect("node 2 shutdown");
    let svc1 = Arc::try_unwrap(svc1)
        .unwrap_or_else(|_| panic!("node 1 service still shared"));
    let svc2 = Arc::try_unwrap(svc2)
        .unwrap_or_else(|_| panic!("node 2 service still shared"));
    drop(svc1.finish().expect("node 1 finish"));
    drop(svc2.finish().expect("node 2 finish"));
    mig.ns_per_unit
}

fn main() {
    println!("== cluster transport ==\n");
    let mut results = Vec::new();

    codec_rows(&mut results);
    rpc_row(&mut results);
    let inproc = migrate_inproc_row(&mut results);
    let tcp = migrate_tcp_row(&mut results);
    if inproc > 0.0 {
        println!(
            "\n  cross-process premium: {:.1}x over in-process",
            tcp / inproc
        );
    }

    let mut doc = BTreeMap::new();
    doc.insert("bench".into(), Json::Str("transport".into()));
    doc.insert(
        "workload".into(),
        Json::Str(format!(
            "{FRAMES} frames/iter codec rows; {RPCS} loopback RPCs/iter; \
             {MOVES} x {SHARDS_PER_MOVE}-shard seal→adopt moves/iter with \
             {STREAMS} warm streams, in-process vs loopback TCP"
        )),
    );
    doc.insert("results".into(), Json::Arr(results));
    let json = Json::Obj(doc);

    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("cargo manifest dir has a parent");
    let path = root.join("BENCH_transport.json");
    std::fs::write(&path, json.to_string_compact() + "\n")
        .expect("write BENCH_transport.json");
    println!("wrote {}", path.display());
    match teda_fpga::util::benchkit::append_trend(root, "transport", &json) {
        Ok(true) => println!("appended run to BENCH_trend.json"),
        Ok(false) => println!("BENCH_trend.json already has this run"),
        Err(e) => eprintln!("warning: trend append failed: {e}"),
    }
}

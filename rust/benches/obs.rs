//! Observability-plane overhead: flight-recorder event cost (enabled
//! and gated off), stage-histogram record cost, Prometheus text
//! rendering, and full scrape round-trip latency.
//!
//! These numbers bound what the coordinator pays for ISSUE 7's
//! instrumentation — the recorder/histogram costs are the per-event
//! prices the hot path quotes, and the scrape side shows the metrics
//! endpoint is cheap enough to poll at 1 Hz without touching workers.
//!
//! Emits `BENCH_obs.json` at the repository root and appends the run
//! to the cumulative `BENCH_trend.json` (per-PR perf trajectory).
//!
//! Run: `cargo bench --bench obs`

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::TcpStream;

use teda_fpga::config::Json;
use teda_fpga::metrics::{Histogram, ServiceMetrics};
use teda_fpga::obs::prometheus::render_prometheus;
use teda_fpga::obs::recorder::{record, recorder, EventKind};
use teda_fpga::obs::MetricsServer;
use teda_fpga::util::benchkit::{black_box, Bench};

/// Events / histogram samples per measured iteration.
const OPS: u64 = 100_000;
/// Scrapes per measured iteration.
const SCRAPES: u64 = 50;

fn num(v: f64) -> Json {
    Json::Num((v * 10.0).round() / 10.0)
}

fn row(results: &mut Vec<Json>, metric: &str, value: f64) {
    let mut row = BTreeMap::new();
    row.insert("metric".into(), Json::Str(metric.into()));
    row.insert("value".into(), num(value));
    results.push(Json::Obj(row));
}

/// One blocking HTTP GET against the metrics endpoint; returns the
/// body length (sanity-checked by the caller).
fn scrape(addr: std::net::SocketAddr) -> usize {
    let mut conn = TcpStream::connect(addr).expect("connect scrape");
    conn.write_all(b"GET /metrics HTTP/1.1\r\nHost: b\r\n\r\n")
        .expect("send scrape");
    let mut body = String::new();
    conn.read_to_string(&mut body).expect("read scrape");
    body.len()
}

fn main() {
    println!("== observability plane ({OPS} ops/iter) ==\n");
    let mut results = Vec::new();

    // 1. Flight recorder, enabled: the seqlock ring push every journaled
    //    coordinator event pays (clock read + 3 atomic stores).
    recorder().configure(true, 4096);
    let rec = Bench::new("event_record")
        .iters(50)
        .units(OPS, "events")
        .run(|| {
            for i in 0..OPS {
                record(
                    EventKind::Dequeue,
                    black_box(i),
                    (i % 256) as u32,
                    (i % 4) as u32,
                );
            }
        });
    row(&mut results, "event_record_ns", rec.ns_per_unit);

    // 2. Flight recorder, disabled: the one relaxed load the gate costs
    //    when tracing is off (`obs.recorder = false`).
    recorder().set_enabled(false);
    let rec_off = Bench::new("event_record_disabled")
        .iters(50)
        .units(OPS, "events")
        .run(|| {
            for i in 0..OPS {
                record(
                    EventKind::Dequeue,
                    black_box(i),
                    (i % 256) as u32,
                    (i % 4) as u32,
                );
            }
        });
    row(&mut results, "event_record_disabled_ns", rec_off.ns_per_unit);
    recorder().set_enabled(true);

    // 3. Stage histogram record: what queue_wait/engine_time/emit_time
    //    add per observation (log2 bucket index + 2 relaxed adds).
    let hist = Histogram::new();
    let h = Bench::new("hist_record")
        .iters(50)
        .units(OPS, "records")
        .run(|| {
            for i in 0..OPS {
                hist.record(black_box(i * 37 + 1));
            }
        });
    row(&mut results, "hist_record_ns", h.ns_per_unit);

    // 4. Prometheus text rendering over a fully populated registry.
    let metrics = ServiceMetrics::new();
    metrics.samples_in.add(1_000_000);
    metrics.verdicts_out.add(1_000_000);
    for i in 0..10_000u64 {
        metrics.latency.record(i * 100 + 1);
        metrics.queue_wait.record(i * 10 + 1);
        metrics.engine_time.record(i * 50 + 1);
        metrics.emit_time.record(i * 5 + 1);
    }
    let render = Bench::new("prometheus_render")
        .iters(50)
        .units(100, "renders")
        .run(|| {
            for _ in 0..100 {
                black_box(render_prometheus(&metrics, None));
            }
        });
    row(&mut results, "prometheus_render_ns", render.ns_per_unit);

    // 5. Full scrape round trip: TCP connect + GET + render + read, the
    //    latency a Prometheus poller actually observes.
    let srv = MetricsServer::start("127.0.0.1:0", metrics.clone(), None)
        .expect("start metrics server");
    let addr = srv.local_addr();
    assert!(scrape(addr) > 0, "scrape returned an empty response");
    let sc = Bench::new("scrape")
        .iters(20)
        .units(SCRAPES, "scrapes")
        .run(|| {
            for _ in 0..SCRAPES {
                black_box(scrape(addr));
            }
        });
    row(&mut results, "scrape_ns", sc.ns_per_unit);
    drop(srv);

    let mut doc = BTreeMap::new();
    doc.insert("bench".into(), Json::Str("obs".into()));
    doc.insert(
        "workload".into(),
        Json::Str(format!(
            "{OPS} recorder/histogram ops per iter, 4096-slot journals, \
             {SCRAPES} scrapes per iter over loopback"
        )),
    );
    doc.insert("results".into(), Json::Arr(results));
    let json = Json::Obj(doc);

    // Always the repository root (one level above the cargo manifest),
    // matching the other BENCH_*.json emitters.
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("cargo manifest dir has a parent");
    let path = root.join("BENCH_obs.json");
    std::fs::write(&path, json.to_string_compact() + "\n")
        .expect("write BENCH_obs.json");
    println!("wrote {}", path.display());
    match teda_fpga::util::benchkit::append_trend(root, "obs", &json) {
        Ok(true) => println!("appended run to BENCH_trend.json"),
        Ok(false) => println!("BENCH_trend.json already has this run"),
        Err(e) => eprintln!("warning: trend append failed: {e}"),
    }
}

//! Batch-native engine kernels: `process_batch` (run-coalesced) vs the
//! per-sample `ingest` loop, per backend, across a run-length sweep.
//!
//! The workload holds total sample count fixed and varies only how many
//! consecutive samples share a stream (the run length): at run length 1
//! every sample pays the per-stream dispatch (map lookup, state
//! resolve), at 1024 the batch kernel amortizes it across the whole
//! run. Single-submit throughput is the coalescing-off baseline for the
//! EXPERIMENTS.md ablation.
//!
//! The global flight recorder stays at its default (enabled), matching
//! production services; nothing here turns it off.
//!
//! Emits `BENCH_engine.json` at the repository root and appends the run
//! to the cumulative `BENCH_trend.json`.
//!
//! Run: `cargo bench --bench engine`

use std::collections::BTreeMap;

use teda_fpga::config::{EnsembleConfig, Json};
use teda_fpga::engine::{Engine, RtlEngine, SoftwareEngine, XlaEngine};
use teda_fpga::ensemble::EnsembleEngine;
use teda_fpga::obs::recorder;
use teda_fpga::runtime::XlaRuntime;
use teda_fpga::stream::Sample;
use teda_fpga::util::benchkit::{black_box, Bench};
use teda_fpga::util::prng::SplitMix64;

const N_FEATURES: usize = 2;
const M: f64 = 3.0;
/// Samples per measured burst (fixed across the run-length sweep).
const BURST: usize = 8_192;
const STREAMS: u64 = 16;
/// Lengths of the consecutive same-stream runs inside each burst.
const RUN_LENS: [usize; 4] = [1, 8, 64, 1024];
/// Run length used for the single-submit (coalescing-off) baseline.
const SINGLE_RL: usize = 64;

/// A burst of `BURST` samples where every maximal same-stream run is
/// exactly `run_len` long: streams rotate round-robin, each contributing
/// `run_len` consecutive samples with monotonic per-stream seqs.
fn workload(run_len: usize, rng: &mut SplitMix64) -> Vec<Sample> {
    let mut out = Vec::with_capacity(BURST);
    let mut seqs = vec![0u64; STREAMS as usize];
    let mut sid = 0u64;
    while out.len() < BURST {
        for _ in 0..run_len.min(BURST - out.len()) {
            let seq = &mut seqs[sid as usize];
            out.push(Sample {
                stream_id: sid,
                seq: *seq,
                values: (0..N_FEATURES).map(|_| rng.normal()).collect(),
            });
            *seq += 1;
        }
        sid = (sid + 1) % STREAMS;
    }
    out
}

/// Per-sample baseline: the pre-coalescing hot path (one map resolve
/// per sample).
fn bench_single(name: &str, eng: &mut dyn Engine, samples: &[Sample]) -> f64 {
    Bench::new(name)
        .iters(30)
        .units(BURST as u64, "samples")
        .run(|| {
            for s in samples {
                black_box(eng.ingest(s).unwrap());
            }
        })
        .throughput
}

/// Run-coalesced batch kernel: one state resolve per run, one reused
/// output buffer per burst.
fn bench_batch(name: &str, eng: &mut dyn Engine, samples: &[Sample]) -> f64 {
    let mut out = Vec::new();
    Bench::new(name)
        .iters(30)
        .units(BURST as u64, "samples")
        .run(|| {
            out.clear();
            eng.process_batch(samples, &mut out).unwrap();
            black_box(out.len());
        })
        .throughput
}

fn num(v: f64) -> Json {
    Json::Num((v * 10.0).round() / 10.0)
}

fn push(results: &mut Vec<Json>, metric: String, value: f64) {
    let mut row = BTreeMap::new();
    row.insert("metric".into(), Json::Str(metric));
    row.insert("value".into(), num(value));
    results.push(Json::Obj(row));
}

/// Sweep one engine: single-submit baseline at `SINGLE_RL`, then the
/// batch kernel across every run length. `make` returns a fresh engine
/// per measurement so map sizes stay comparable across backends.
fn sweep(
    results: &mut Vec<Json>,
    label: &str,
    mut make: impl FnMut() -> Box<dyn Engine>,
) {
    let mut rng = SplitMix64::new(0x7EDA_BA7C);
    let single_wl = workload(SINGLE_RL, &mut rng);
    let single = bench_single(
        &format!("{label}_single"),
        make().as_mut(),
        &single_wl,
    );
    println!("{label:>9} single rl{SINGLE_RL}: {single:>12.0} samples/s");
    push(results, format!("{label}_single_sps"), single);

    for rl in RUN_LENS {
        let wl = workload(rl, &mut rng);
        let batch = bench_batch(
            &format!("{label}_batch_rl{rl}"),
            make().as_mut(),
            &wl,
        );
        println!("{label:>9} batch  rl{rl}: {batch:>12.0} samples/s");
        push(results, format!("{label}_batch_rl{rl}_sps"), batch);
    }
}

fn main() {
    assert!(
        recorder().is_enabled(),
        "flight recorder must stay on for this bench"
    );
    println!(
        "== engine kernels ({STREAMS} streams, bursts of {BURST}, run \
         lengths {RUN_LENS:?}, recorder on) ==\n"
    );
    let mut results = Vec::new();

    sweep(&mut results, "software", || {
        Box::new(SoftwareEngine::new(N_FEATURES, M))
    });
    sweep(&mut results, "rtl", || {
        Box::new(RtlEngine::new(N_FEATURES, M))
    });
    let ens_cfg = EnsembleConfig::default();
    sweep(&mut results, "ensemble", || {
        Box::new(EnsembleEngine::new(&ens_cfg, N_FEATURES).unwrap())
    });

    // XLA rows ship only when the AOT artifact is present (same gate as
    // the engine tests); the bench-gate treats them as optional.
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if std::path::Path::new(dir).join("manifest.json").exists() {
        let rt = XlaRuntime::new(dir).unwrap();
        sweep(&mut results, "xla", || {
            Box::new(XlaEngine::new(&rt, N_FEATURES, 1).unwrap())
        });
    } else {
        eprintln!("artifacts missing; skipping XLA engine rows");
    }

    let mut doc = BTreeMap::new();
    doc.insert("bench".into(), Json::Str("engine".into()));
    doc.insert(
        "workload".into(),
        Json::Str(format!(
            "{STREAMS} streams, bursts of {BURST}, batch vs single per \
             backend, run-length sweep {RUN_LENS:?} (single baseline at \
             rl{SINGLE_RL}), flight recorder on"
        )),
    );
    doc.insert("results".into(), Json::Arr(results));
    let json = Json::Obj(doc);

    // Always the repository root (one level above the cargo manifest),
    // matching the other BENCH_*.json emitters.
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("cargo manifest dir has a parent");
    let path = root.join("BENCH_engine.json");
    std::fs::write(&path, json.to_string_compact() + "\n")
        .expect("write BENCH_engine.json");
    println!("wrote {}", path.display());
    match teda_fpga::util::benchkit::append_trend(root, "engine", &json) {
        Ok(true) => println!("appended run to BENCH_trend.json"),
        Ok(false) => println!("BENCH_trend.json already has this run"),
        Err(e) => eprintln!("warning: trend append failed: {e}"),
    }
}

//! Cluster hardening costs: what the dynamic-membership machinery
//! actually pays —
//!
//! - **join-to-routable**: a cold node running `--join` against a live
//!   sponsor, measured from `ClusterNode::start` until the table and
//!   roster are installed (the node can route, though it owns nothing
//!   yet);
//! - **cross-node shard move**: one load-driven `migrate_to_peer`
//!   step — Table push + Expect + Seal hauling the sealed bundle +
//!   barrier + Adopt, all framed RPCs;
//! - **buffered-burst drain**: replaying a backlog that parked in the
//!   `ClusterHandle` ingest buffer while a peer was down, once the
//!   peer is back.
//!
//! Unix-socket transport throughout: deterministic addresses, no port
//! races, and the framing/RPC path is identical to TCP (whose raw
//! round-trip cost `benches/transport.rs` already tracks).
//!
//! Emits `BENCH_cluster.json` at the repository root and appends the
//! run to the cumulative `BENCH_trend.json`.
//!
//! Run: `cargo bench --bench cluster`

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;

use teda_fpga::config::{ClusterConfig, Json, ServiceConfig, ShardingConfig};
use teda_fpga::coordinator::{ClusterNode, Service};
use teda_fpga::stream::Sample;
use teda_fpga::util::benchkit::{black_box, Bench};
use teda_fpga::util::prng::SplitMix64;

/// Join → leave cycles measured one per iteration.
const JOIN_ITERS: u64 = 10;
/// Shard moves per measured iteration.
const MOVES: u64 = 10;
const SHARDS_PER_MOVE: usize = 4;
/// Streams warmed before moves / the parked burst.
const STREAMS: u64 = 16;
const WARM_SAMPLES: u64 = 60;
/// Per-stream samples submitted while the peer is down (these park).
const BURST_SAMPLES: u64 = 100;
/// Kill → park → restart → drain cycles averaged for the drain row.
const DRAIN_CYCLES: u64 = 3;

fn num(v: f64) -> Json {
    Json::Num((v * 10.0).round() / 10.0)
}

fn row(results: &mut Vec<Json>, metric: &str, value: f64) {
    let mut row = BTreeMap::new();
    row.insert("metric".into(), Json::Str(metric.into()));
    row.insert("value".into(), num(value));
    results.push(Json::Obj(row));
}

fn sample(sid: u64, seq: u64) -> Sample {
    let mut rng = SplitMix64::new(sid.wrapping_mul(0x9E37) ^ seq);
    Sample {
        stream_id: sid,
        seq,
        values: vec![rng.uniform(-2.0, 2.0), rng.uniform(-2.0, 2.0)],
    }
}

fn svc_cfg() -> ServiceConfig {
    ServiceConfig {
        workers: 2,
        n_features: 2,
        queue_capacity: 256,
        sharding: ShardingConfig { virtual_shards: 32, ..Default::default() },
        ..Default::default()
    }
}

fn pair_cfg(dir: &Path, tag: &str) -> (ClusterConfig, ClusterConfig) {
    let a = format!("unix:{}", dir.join(format!("{tag}-n1.sock")).display());
    let b = format!("unix:{}", dir.join(format!("{tag}-n2.sock")).display());
    (
        ClusterConfig {
            node_id: 1,
            listen: Some(a.clone()),
            peers: vec![format!("2={b}")],
            heartbeat_ms: 500,
            failover_ms: 0,
            ..Default::default()
        },
        ClusterConfig {
            node_id: 2,
            listen: Some(b),
            peers: vec![format!("1={a}")],
            heartbeat_ms: 500,
            failover_ms: 0,
            ..Default::default()
        },
    )
}

fn start_pair(
    dir: &Path,
    tag: &str,
) -> (Arc<Service>, ClusterNode, Arc<Service>, ClusterNode, ClusterConfig)
{
    let (c1, c2) = pair_cfg(dir, tag);
    let svc1 = Arc::new(Service::start(svc_cfg()).expect("node 1 svc"));
    let svc2 = Arc::new(Service::start(svc_cfg()).expect("node 2 svc"));
    let n1 = ClusterNode::start(svc1.clone(), &c1).expect("node 1");
    let n2 = ClusterNode::start(svc2.clone(), &c2).expect("node 2");
    assert_eq!(n1.hello_peers(), 1, "node 2 must answer hello");
    (svc1, n1, svc2, n2, c2)
}

fn finish(svc: Arc<Service>, tag: &str) {
    let svc = Arc::try_unwrap(svc)
        .unwrap_or_else(|_| panic!("{tag} service still shared"));
    drop(svc.finish().expect("finish"));
}

/// Time from `ClusterNode::start` with `join` set until the joiner is
/// routable (table + roster installed, peers helloed). Each iteration
/// joins as a NEW member (the previous cycle `leave`s cleanly), so the
/// sponsor walks the full admit path every time: roster install,
/// epoch+1 re-broadcast, join gossip, JoinOk.
fn join_row(results: &mut Vec<Json>, dir: &Path) {
    let (svc1, n1, svc2, n2, _) = start_pair(dir, "join");
    let sponsor = n1.bound_addr();
    let svc3 = Arc::new(Service::start(svc_cfg()).expect("joiner svc"));
    let mut round = 0u64;
    let bench = Bench::new("join_to_routable")
        .iters(JOIN_ITERS as usize)
        .units(1, "joins")
        .run(|| {
            round += 1;
            let c3 = ClusterConfig {
                node_id: 3,
                listen: Some(format!(
                    "unix:{}",
                    dir.join(format!("join-n3-{round}.sock")).display()
                )),
                peers: vec![],
                join: Some(sponsor.clone()),
                heartbeat_ms: 500,
                failover_ms: 0,
                ..Default::default()
            };
            let n3 = ClusterNode::start(svc3.clone(), &c3).expect("join");
            black_box(n3.table());
            n3.leave().expect("leave");
            n3.shutdown().expect("joiner shutdown");
        });
    row(results, "join_to_routable_ns", bench.ns_per_unit);
    println!("  join → routable: {:.0} ns", bench.ns_per_unit);
    n1.shutdown().expect("node 1 shutdown");
    n2.shutdown().expect("node 2 shutdown");
    finish(svc1, "node 1");
    finish(svc2, "node 2");
    finish(svc3, "joiner");
}

/// One cross-node shard move — the step the load-driven rebalancer
/// takes when it sheds hot shards to the coldest peer.
fn shard_move_row(results: &mut Vec<Json>, dir: &Path) {
    let (svc1, n1, svc2, n2, _) = start_pair(dir, "move");
    let ingest = n1.handle();
    for seq in 0..WARM_SAMPLES {
        ingest
            .submit_batch((0..STREAMS).map(|sid| sample(sid, seq)).collect())
            .expect("warm");
    }
    let shards: Vec<u32> = n1
        .owned_shards()
        .into_iter()
        .take(SHARDS_PER_MOVE)
        .collect();
    let mut here = true;
    let bench = Bench::new("shard_move")
        .iters(20)
        .units(MOVES, "moves")
        .run(|| {
            for _ in 0..MOVES {
                if here {
                    n1.migrate_to_peer(2, &shards).expect("push 1→2");
                } else {
                    n2.migrate_to_peer(1, &shards).expect("push 2→1");
                }
                here = !here;
            }
        });
    row(results, "shard_move_ns", bench.ns_per_unit);
    println!(
        "  cross-node shard move ({SHARDS_PER_MOVE} shards): {:.0} ns",
        bench.ns_per_unit
    );
    drop(ingest);
    n1.shutdown().expect("node 1 shutdown");
    n2.shutdown().expect("node 2 shutdown");
    finish(svc1, "node 1");
    finish(svc2, "node 2");
}

/// Drain cost per parked sample: kill node 2, park a burst of its
/// share in node 1's ingest buffer, bring node 2 back, and measure
/// replaying the backlog until the buffer is empty. Hand-timed — the
/// benchkit warmup pass would drain the one-shot backlog before the
/// measured pass — with a few kill→park→restart cycles averaged.
fn burst_drain_row(results: &mut Vec<Json>, dir: &Path) {
    let (svc1, n1, svc2, mut n2, c2) = start_pair(dir, "burst");
    let ingest = n1.handle();
    for seq in 0..WARM_SAMPLES {
        ingest
            .submit_batch((0..STREAMS).map(|sid| sample(sid, seq)).collect())
            .expect("warm");
    }
    let mut drained = 0u64;
    let mut spent_ns = 0f64;
    let mut seq0 = WARM_SAMPLES;
    for _cycle in 0..DRAIN_CYCLES {
        // Down: node 2's control plane dies (its service survives —
        // this is the failover *window*, not a data loss drill).
        n2.shutdown().expect("node 2 shutdown");
        for seq in seq0..seq0 + BURST_SAMPLES {
            ingest
                .submit_batch(
                    (0..STREAMS).map(|sid| sample(sid, seq)).collect(),
                )
                .expect("burst must park, not error");
        }
        seq0 += BURST_SAMPLES;
        let parked = ingest.parked() as u64;
        assert!(parked > 0, "node 2's share of the burst must park");
        // Back: rebind over the stale socket (the designed restart
        // path); node 1's peer client reconnects on the next RPC.
        n2 = ClusterNode::start(svc2.clone(), &c2).expect("restart");
        let t0 = std::time::Instant::now();
        while ingest.flush_parked() > 0 {}
        spent_ns += t0.elapsed().as_nanos() as f64;
        drained += parked;
    }
    let ns_per_sample = spent_ns / drained as f64;
    row(results, "burst_drain_ns", ns_per_sample);
    println!(
        "  buffered-burst drain: {drained} samples over {DRAIN_CYCLES} \
         cycles, {ns_per_sample:.0} ns/sample"
    );
    drop(ingest);
    n1.shutdown().expect("node 1 shutdown");
    n2.shutdown().expect("node 2 restart shutdown");
    finish(svc1, "node 1");
    finish(svc2, "node 2");
}

fn main() {
    println!("== cluster hardening ==\n");
    let dir = teda_fpga::util::unique_temp_dir("bench-cluster");
    std::fs::create_dir_all(&dir).expect("temp dir");
    let mut results = Vec::new();

    join_row(&mut results, &dir);
    shard_move_row(&mut results, &dir);
    burst_drain_row(&mut results, &dir);

    let mut doc = BTreeMap::new();
    doc.insert("bench".into(), Json::Str("cluster".into()));
    doc.insert(
        "workload".into(),
        Json::Str(format!(
            "{JOIN_ITERS} join→leave cycles; {MOVES} x \
             {SHARDS_PER_MOVE}-shard cross-node moves/iter with {STREAMS} \
             warm streams; {BURST_SAMPLES}-deep per-stream burst parked \
             against a down peer then drained, unix-socket transport"
        )),
    );
    doc.insert("results".into(), Json::Arr(results));
    let json = Json::Obj(doc);

    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("cargo manifest dir has a parent");
    let path = root.join("BENCH_cluster.json");
    std::fs::write(&path, json.to_string_compact() + "\n")
        .expect("write BENCH_cluster.json");
    println!("wrote {}", path.display());
    match teda_fpga::util::benchkit::append_trend(root, "cluster", &json) {
        Ok(true) => println!("appended run to BENCH_trend.json"),
        Ok(false) => println!("BENCH_trend.json already has this run"),
        Err(e) => eprintln!("warning: trend append failed: {e}"),
    }
}

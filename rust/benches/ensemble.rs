//! Ensemble throughput: samples/sec for 1, 3, 5 members × combiner.
//!
//! Establishes the perf trajectory baseline for the fusion layer: the
//! cost of quorum alignment + fusion on top of N member detectors.
//! Emits `BENCH_ensemble.json` at the repository root.
//!
//! Run: `cargo bench --bench ensemble`

use std::collections::BTreeMap;

use teda_fpga::config::{CombinerKind, EnsembleConfig, Json};
use teda_fpga::engine::Engine as _;
use teda_fpga::ensemble::EnsembleEngine;
use teda_fpga::stream::Sample;
use teda_fpga::util::benchkit::{black_box, Bench};
use teda_fpga::util::prng::SplitMix64;

const STREAMS: u64 = 8;
const PER_STREAM: usize = 2_000;
const N_FEATURES: usize = 2;

fn workload() -> Vec<Sample> {
    let mut rng = SplitMix64::new(0x7EDA);
    let mut out = Vec::with_capacity(STREAMS as usize * PER_STREAM);
    for seq in 0..PER_STREAM {
        for sid in 0..STREAMS {
            out.push(Sample {
                stream_id: sid,
                seq: seq as u64,
                values: (0..N_FEATURES).map(|_| rng.normal()).collect(),
            });
        }
    }
    out
}

fn main() {
    // Software-only member rosters: this measures the fusion layer, not
    // the (much slower) cycle-accurate RTL simulation.
    let rosters: [(usize, &str); 3] = [
        (1, "teda:m=3"),
        (3, "teda:m=3+teda:m=2.5+msigma:m=3"),
        (5, "teda:m=3+teda:m=2.5+teda:m=4+msigma:m=3+zscore:m=3,w=64"),
    ];
    let combiners = [
        CombinerKind::Majority,
        CombinerKind::WeightedScore,
        CombinerKind::Adaptive,
    ];
    let samples = workload();
    let total = samples.len() as u64;
    println!(
        "== ensemble throughput ({} streams × {} samples, N={}) ==",
        STREAMS, PER_STREAM, N_FEATURES
    );

    let mut results = Vec::new();
    for (n_members, roster) in rosters {
        for combiner in combiners {
            let cfg = EnsembleConfig::from_member_list(roster, combiner)
                .expect("roster");
            let report = Bench::new(format!(
                "ensemble_{n_members}members_{combiner}"
            ))
            .iters(10)
            .units(total, "samples")
            .run(|| {
                let mut eng =
                    EnsembleEngine::new(&cfg, N_FEATURES).unwrap();
                let mut got = 0usize;
                for s in &samples {
                    got += eng.ingest(s).unwrap().len();
                }
                got += eng.flush().unwrap().len();
                assert_eq!(got, total as usize);
                black_box(got);
            });
            let mut row = BTreeMap::new();
            row.insert(
                "members".to_string(),
                Json::Num(n_members as f64),
            );
            row.insert(
                "combiner".to_string(),
                Json::Str(combiner.to_string()),
            );
            row.insert(
                "samples_per_sec".to_string(),
                Json::Num(report.throughput.round()),
            );
            row.insert(
                "ns_per_sample".to_string(),
                Json::Num((report.ns_per_unit * 10.0).round() / 10.0),
            );
            results.push(Json::Obj(row));
        }
    }

    let mut doc = BTreeMap::new();
    doc.insert("bench".to_string(), Json::Str("ensemble".to_string()));
    doc.insert(
        "workload".to_string(),
        Json::Str(format!(
            "{STREAMS} streams x {PER_STREAM} samples, N={N_FEATURES}, \
             interleaved normal data"
        )),
    );
    doc.insert("unit".to_string(), Json::Str("samples/sec".to_string()));
    doc.insert("results".to_string(), Json::Arr(results));
    let json = Json::Obj(doc).to_string_compact();

    // Always the repository root (one level above the cargo manifest),
    // regardless of the CWD the bench is launched from — ROADMAP's
    // trend tracking expects the file there.
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("cargo manifest dir has a parent")
        .join("BENCH_ensemble.json");
    std::fs::write(&path, json + "\n").expect("write BENCH_ensemble.json");
    println!("wrote {}", path.display());
}

//! Table 5 bench: per-sample classification time on every platform we
//! can measure on this host, against the modeled FPGA time.
//!
//! Run: `cargo bench --bench table5_platforms`
//! (the example `platform_comparison` adds the Python rows; this bench
//! keeps to in-process platforms so `cargo bench` stays hermetic)

use teda_fpga::rtl::TedaRtl;
use teda_fpga::runtime::XlaRuntime;
use teda_fpga::synth::PipelineTiming;
use teda_fpga::teda::TedaDetector;
use teda_fpga::util::benchkit::{black_box, Bench};
use teda_fpga::util::prng::SplitMix64;

const SAMPLES: usize = 200_000;

fn main() {
    let fpga_ns =
        PipelineTiming::analyze(TedaRtl::new(2, 3.0).unwrap().netlist())
            .teda_time_ns;
    let mut rows: Vec<(String, f64)> =
        vec![("FPGA (modeled)".into(), fpga_ns)];

    // Rust software.
    let mut rng = SplitMix64::new(3);
    let samples: Vec<Vec<f64>> = (0..SAMPLES)
        .map(|_| vec![rng.next_f64(), rng.next_f64()])
        .collect();
    let mut det = TedaDetector::new(2, 3.0);
    let r = Bench::new("rust_software_teda")
        .iters(15)
        .units(SAMPLES as u64, "samples")
        .run(|| {
            det.reset();
            for s in &samples {
                black_box(det.step(s));
            }
        });
    rows.push(("Rust software".into(), r.ns_per_unit));

    // RTL simulator.
    let s32: Vec<Vec<f32>> = samples[..50_000]
        .iter()
        .map(|s| s.iter().map(|&v| v as f32).collect())
        .collect();
    let mut rtl = TedaRtl::new(2, 3.0).unwrap();
    let r = Bench::new("rust_rtl_simulator")
        .iters(10)
        .units(s32.len() as u64, "samples")
        .run(|| {
            rtl.reset();
            for s in &s32 {
                black_box(rtl.clock(s).unwrap());
            }
        });
    rows.push(("Rust RTL simulator".into(), r.ns_per_unit));

    // XLA artifact (batched).
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if std::path::Path::new(dir).join("manifest.json").exists() {
        let rt = XlaRuntime::new(dir).unwrap();
        let spec = rt.manifest().select(2, 1024).unwrap().clone();
        let exe = rt.load(&spec.name).unwrap();
        let (s, t, n) = (spec.s, spec.t, spec.n);
        let mut rng = SplitMix64::new(5);
        let mu = vec![0f32; s * n];
        let var = vec![0f32; s];
        let k = vec![1f32; s];
        let x: Vec<f32> =
            (0..s * t * n).map(|_| rng.next_f64() as f32).collect();
        let r = Bench::new(format!("xla_batched_{}", spec.name))
            .iters(100)
            .units((s * t) as u64, "samples")
            .run(|| {
                black_box(exe.run_f32(&[&mu, &var, &k, &x]).unwrap());
            });
        rows.push(("XLA/Pallas (PJRT CPU)".into(), r.ns_per_unit));
    } else {
        eprintln!("(artifacts missing — XLA row skipped)");
    }

    println!("\nTable 5 (in-process platforms):");
    println!("| {:<24} | {:>12} | {:>10} |", "Platform", "ns/sample", "vs FPGA");
    for (name, ns) in &rows {
        println!(
            "| {:<24} | {:>12.1} | {:>9.2}× |",
            name,
            ns,
            ns / fpga_ns
        );
    }
}

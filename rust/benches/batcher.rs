//! Dynamic-batching bench: XLA engine chunk cost vs batch occupancy.
//!
//! Sweeps `min_ready` (how many full stream-chunks the batcher waits
//! for) and reports per-sample amortized cost — the ablation behind the
//! coordinator's batching policy (DESIGN.md §7 L3 knobs).
//!
//! Run: `cargo bench --bench batcher`

use teda_fpga::engine::{Engine, XlaEngine};
use teda_fpga::runtime::XlaRuntime;
use teda_fpga::stream::Sample;
use teda_fpga::util::benchkit::{black_box, Bench};
use teda_fpga::util::prng::SplitMix64;

fn main() {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !std::path::Path::new(dir).join("manifest.json").exists() {
        eprintln!("artifacts missing — run `make artifacts`");
        return;
    }
    let rt = XlaRuntime::new(dir).unwrap();
    let spec = rt.manifest().select(2, 1024).unwrap().clone();
    println!(
        "== batcher sweep on {} (S={}, T={}, N={}) ==",
        spec.name, spec.s, spec.t, spec.n
    );

    let streams = spec.s as u64;
    let per_stream = spec.t * 4;
    let mut rng = SplitMix64::new(11);
    // Pre-generate an interleaved workload.
    let mut workload: Vec<Sample> = Vec::new();
    for seq in 0..per_stream {
        for sid in 0..streams {
            workload.push(Sample {
                stream_id: sid,
                seq: seq as u64,
                values: vec![rng.next_f64(), rng.next_f64()],
            });
        }
    }
    let total = workload.len() as u64;

    for min_ready in [1usize, 4, 8, spec.s] {
        let mut eng = XlaEngine::new(&rt, 2, spec.s * spec.t)
            .unwrap()
            .with_min_ready(min_ready);
        let report = Bench::new(format!("xla_engine_min_ready_{min_ready}"))
            .iters(8)
            .units(total, "samples")
            .run(|| {
                let mut got = 0usize;
                for s in &workload {
                    got += eng.ingest(s).unwrap().len();
                }
                got += eng.flush().unwrap().len();
                black_box(got);
            });
        println!(
            "  min_ready={min_ready:<3} -> {:.0} ns/sample, {} chunks so far",
            report.ns_per_unit, eng.chunks_executed
        );
    }
}

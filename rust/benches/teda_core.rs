//! Microbenches for the TEDA core: the recurrence step across feature
//! widths and precisions, plus the comparison baselines.
//!
//! Run: `cargo bench --bench teda_core`

use std::time::Duration;

use teda_fpga::baselines::{AnomalyDetector, MSigmaDetector, SlidingZScore};
use teda_fpga::teda::{TedaDetector, TedaState};
use teda_fpga::util::benchkit::{black_box, Bench};
use teda_fpga::util::prng::SplitMix64;

const SAMPLES: usize = 100_000;

fn gen(n: usize, seed: u64) -> Vec<Vec<f64>> {
    let mut rng = SplitMix64::new(seed);
    (0..SAMPLES)
        .map(|_| (0..n).map(|_| rng.next_f64()).collect())
        .collect()
}

fn main() {
    println!("== teda_core microbenches ({SAMPLES} samples/iter) ==");

    for n in [1usize, 2, 4, 8] {
        let samples = gen(n, 42);
        let mut st = TedaState::<f64>::new(n);
        Bench::new(format!("teda_state_f64_n{n}"))
            .iters(20)
            .warmup(Duration::from_millis(200))
            .units(SAMPLES as u64, "samples")
            .run(|| {
                st.reset();
                for s in &samples {
                    black_box(st.step(s, 3.0));
                }
            });
    }

    // f32 (the RTL-equivalent datapath precision).
    {
        let samples = gen(2, 43);
        let s32: Vec<Vec<f32>> = samples
            .iter()
            .map(|s| s.iter().map(|&v| v as f32).collect())
            .collect();
        let mut st = TedaState::<f32>::new(2);
        Bench::new("teda_state_f32_n2")
            .iters(20)
            .units(SAMPLES as u64, "samples")
            .run(|| {
                st.reset();
                for s in &s32 {
                    black_box(st.step(s, 3.0f32));
                }
            });
    }

    // Full detector (flag counters etc.).
    {
        let samples = gen(2, 44);
        let mut det = TedaDetector::new(2, 3.0);
        Bench::new("teda_detector_n2")
            .iters(20)
            .units(SAMPLES as u64, "samples")
            .run(|| {
                det.reset();
                for s in &samples {
                    black_box(det.step(s));
                }
            });
    }

    // Baselines on the same stream, for the efficiency argument (§2:
    // TEDA's recursion is O(1)/sample like m-sigma, while the windowed
    // z-score pays ring-buffer traffic).
    {
        let samples = gen(2, 45);
        Bench::new("baseline_msigma_n2")
            .iters(20)
            .units(SAMPLES as u64, "samples")
            .run(|| {
                let mut det = MSigmaDetector::new(2, 3.0);
                for s in &samples {
                    black_box(det.step(s));
                }
            });
        Bench::new("baseline_sliding_zscore_w128_n2")
            .iters(20)
            .units(SAMPLES as u64, "samples")
            .run(|| {
                let mut det = SlidingZScore::new(2, 3.0, 128);
                for s in &samples {
                    black_box(det.step(s));
                }
            });
    }
}

//! Table 4 bench: the RTL pipeline — modeled FPGA timing (the paper's
//! numbers) next to the measured cost of *simulating* it, across
//! feature widths.
//!
//! Run: `cargo bench --bench table4_rtl_time`

use teda_fpga::rtl::TedaRtl;
use teda_fpga::synth::PipelineTiming;
use teda_fpga::util::benchkit::{black_box, Bench};
use teda_fpga::util::prng::SplitMix64;

const SAMPLES: usize = 20_000;

fn main() {
    println!("== table4: modeled FPGA vs measured simulator ==\n");
    println!("  N | t_c (ns) | d (ns) | modeled MSPS | simulated MSPS");
    println!("----|----------|--------|--------------|----------------");
    for n in [1usize, 2, 4, 8] {
        let rtl = TedaRtl::new(n, 3.0).unwrap();
        let t = PipelineTiming::analyze(rtl.netlist());

        let mut rng = SplitMix64::new(7);
        let samples: Vec<Vec<f32>> = (0..SAMPLES)
            .map(|_| (0..n).map(|_| rng.next_f64() as f32).collect())
            .collect();
        let mut pipe = TedaRtl::new(n, 3.0).unwrap();
        let report = Bench::new(format!("rtl_sim_clock_n{n}"))
            .iters(10)
            .units(SAMPLES as u64, "samples")
            .run(|| {
                pipe.reset();
                for s in &samples {
                    black_box(pipe.clock(s).unwrap());
                }
            });
        println!(
            " {n:>2} | {:>8.0} | {:>6.0} | {:>12.2} | {:>14.3}",
            t.critical_ns,
            t.delay_ns,
            t.throughput_sps / 1e6,
            report.throughput / 1e6
        );
    }
    println!(
        "\npaper's Table 4 (N=2): t_c=138 ns, delay=414 ns, 7.2 MSPS \
         (modeled row must match)"
    );
}

//! Elastic sharding: routing cost, live-migration latency, and
//! steady-state service throughput before/after a rebalance.
//!
//! Emits `BENCH_shard.json` at the repository root and appends the run
//! to the cumulative `BENCH_trend.json` (per-PR perf trajectory).
//!
//! Run: `cargo bench --bench shard`

use std::collections::BTreeMap;

use teda_fpga::config::{EngineKind, Json, ServiceConfig, ShardingConfig};
use teda_fpga::coordinator::{Service, ShardMap, ShardTable};
use teda_fpga::stream::Sample;
use teda_fpga::util::benchkit::{black_box, Bench};
use teda_fpga::util::prng::SplitMix64;

const STREAMS: u64 = 64;
const WORKERS: usize = 4;
/// Samples per stream folded in before migrations are measured (warm
/// engine state makes the seal/restore path carry real snapshots).
const WARM: u64 = 500;
/// Samples per throughput measurement burst.
const BURST: usize = 8_192;

fn cfg() -> ServiceConfig {
    ServiceConfig {
        engine: EngineKind::Software,
        workers: WORKERS,
        n_features: 2,
        queue_capacity: 4096,
        sharding: ShardingConfig { virtual_shards: 256, ..Default::default() },
        ..Default::default()
    }
}

fn burst(rng: &mut SplitMix64, seq: &mut u64) -> Vec<Sample> {
    let mut out = Vec::with_capacity(BURST);
    for _ in 0..BURST / STREAMS as usize {
        for sid in 0..STREAMS {
            out.push(Sample {
                stream_id: sid,
                seq: *seq,
                values: vec![rng.normal(), rng.normal()],
            });
        }
        *seq += 1;
    }
    out
}

/// Measure end-to-end throughput: submit a burst, drain all verdicts.
fn throughput(svc: &Service, rng: &mut SplitMix64, seq: &mut u64) -> f64 {
    let report = Bench::new("service_throughput")
        .iters(30)
        .units(BURST as u64, "samples")
        .run(|| {
            svc.submit_batch(burst(rng, seq)).unwrap();
            let mut got = 0usize;
            while got < BURST {
                let drained = svc.poll_results().len();
                got += drained;
                if drained == 0 {
                    std::thread::yield_now();
                }
            }
        });
    report.throughput
}

fn num(v: f64) -> Json {
    Json::Num((v * 10.0).round() / 10.0)
}

fn main() {
    println!(
        "== elastic sharding ({STREAMS} streams, {WORKERS} workers, 256 \
         virtual shards) ==\n"
    );
    let mut results = Vec::new();

    // 1. Pure routing: table snapshot + hash + lookup.
    let table = ShardTable::new_uniform(256, WORKERS);
    let route = Bench::new("route")
        .iters(200)
        .units(10_000, "routes")
        .run(|| {
            let mut acc = 0usize;
            for sid in 0..10_000u64 {
                acc += table.route(black_box(sid)).0;
            }
            black_box(acc);
        });
    let mut row = BTreeMap::new();
    row.insert("metric".into(), Json::Str("route_ns".into()));
    row.insert("value".into(), num(route.ns_per_unit));
    results.push(Json::Obj(row));

    // 1b. Routing through the live shard map: one atomic pointer load
    //     per route (the lock-free steady-state submit path) + hash +
    //     lookup — what every submit actually pays.
    let map = ShardMap::new(ShardTable::new_uniform(256, WORKERS));
    let route_snap = Bench::new("route_snapshot")
        .iters(200)
        .units(10_000, "routes")
        .run(|| {
            let mut acc = 0usize;
            for sid in 0..10_000u64 {
                acc += map.load().route(black_box(sid)).0;
            }
            black_box(acc);
        });
    let mut row = BTreeMap::new();
    row.insert("metric".into(), Json::Str("route_snapshot_ns".into()));
    row.insert("value".into(), num(route_snap.ns_per_unit));
    results.push(Json::Obj(row));

    // 2. Live service: warm up, measure steady-state throughput,
    //    migrate half the shard space back and forth (timed), then
    //    measure throughput again after a scale-out rebalance.
    let svc = Service::start(cfg()).unwrap();
    let mut rng = SplitMix64::new(0x7EDA);
    let mut seq = 0u64;
    let warm_bursts = WARM / (BURST as u64 / STREAMS);
    for _ in 0..warm_bursts {
        svc.submit_batch(burst(&mut rng, &mut seq)).unwrap();
    }
    // Fully drain the warmup so every measured iteration starts from a
    // verdict-balanced service.
    let mut pending = warm_bursts as usize * BURST;
    while pending > 0 {
        let drained = svc.poll_results().len();
        pending -= drained;
        if drained == 0 {
            std::thread::yield_now();
        }
    }

    // Per-sample submit path for contrast with the batched one (the
    // batching win is the ratio of these two).
    let single = Bench::new("service_throughput_single")
        .iters(10)
        .units(BURST as u64, "samples")
        .run(|| {
            for s in burst(&mut rng, &mut seq) {
                svc.submit(s).unwrap();
            }
            let mut got = 0usize;
            while got < BURST {
                let drained = svc.poll_results().len();
                got += drained;
                if drained == 0 {
                    std::thread::yield_now();
                }
            }
        });
    println!(
        "\nsteady-state single-submit: {:.0} samples/s",
        single.throughput
    );

    let before = throughput(&svc, &mut rng, &mut seq);
    println!("steady-state before rebalance: {before:.0} samples/s");

    // Migration latency: move worker 0's shards to worker 1 and back —
    // each iteration is two full seal → barrier → adopt handoffs over
    // real resident stream state.
    let shards0 = svc.table().shards_on(0);
    let mig = Bench::new("migrate_roundtrip").iters(40).run(|| {
        let moves_away: Vec<(u32, usize)> =
            shards0.iter().map(|&s| (s, 1)).collect();
        svc.migrate_shards(&moves_away).unwrap();
        let moves_back: Vec<(u32, usize)> =
            shards0.iter().map(|&s| (s, 0)).collect();
        svc.migrate_shards(&moves_back).unwrap();
    });
    let migration_ns = mig.mean.as_nanos() as f64 / 2.0; // per one-way move
    let mut row = BTreeMap::new();
    row.insert("metric".into(), Json::Str("migration_ns".into()));
    row.insert("value".into(), num(migration_ns));
    row.insert("shards_per_move".into(), Json::Num(shards0.len() as f64));
    results.push(Json::Obj(row));

    // Scale out + rebalance, then re-measure steady state.
    svc.scale_to(WORKERS * 2).unwrap();
    let after = throughput(&svc, &mut rng, &mut seq);
    println!(
        "steady-state after scale_to({}): {after:.0} samples/s",
        WORKERS * 2
    );
    let metrics = svc.metrics();
    let migrations = metrics.migrations.get();
    let streams_moved = metrics.streams_migrated.get();
    let p99_migration = metrics.migration_time.quantile(0.99);
    svc.finish().unwrap();

    for (metric, value) in [
        ("throughput_single_sps", single.throughput),
        ("throughput_before_sps", before),
        ("throughput_after_rebalance_sps", after),
        ("migration_p99_ns", p99_migration as f64),
        ("migrations_total", migrations as f64),
        ("streams_migrated_total", streams_moved as f64),
    ] {
        let mut row = BTreeMap::new();
        row.insert("metric".into(), Json::Str(metric.into()));
        row.insert("value".into(), num(value));
        results.push(Json::Obj(row));
    }

    let mut doc = BTreeMap::new();
    doc.insert("bench".into(), Json::Str("shard".into()));
    doc.insert(
        "workload".into(),
        Json::Str(format!(
            "{STREAMS} streams × software engine, {WORKERS}→{} workers, \
             256 virtual shards, bursts of {BURST}",
            WORKERS * 2
        )),
    );
    doc.insert("results".into(), Json::Arr(results));
    let json = Json::Obj(doc);

    // Always the repository root (one level above the cargo manifest),
    // matching the other BENCH_*.json emitters.
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("cargo manifest dir has a parent");
    let path = root.join("BENCH_shard.json");
    std::fs::write(&path, json.to_string_compact() + "\n")
        .expect("write BENCH_shard.json");
    println!("wrote {}", path.display());
    match teda_fpga::util::benchkit::append_trend(root, "shard", &json) {
        Ok(true) => println!("appended run to BENCH_trend.json"),
        Ok(false) => println!("BENCH_trend.json already has this run"),
        Err(e) => eprintln!("warning: trend append failed: {e}"),
    }
}

//! Durable checkpoint persistence: snapshot size per engine kind, and
//! the encode / decode / restore latencies a failover actually pays.
//!
//! Emits `BENCH_persist.json` at the repository root. The XLA row is
//! codec-only (a synthetic carry + buffered chunks — the AOT artifacts
//! are not required to measure the persistence layer).
//!
//! Run: `cargo bench --bench persist`

use std::collections::BTreeMap;

use teda_fpga::config::{CombinerKind, EnsembleConfig, Json};
use teda_fpga::coordinator::StateCheckpoint;
use teda_fpga::engine::{
    Engine, RtlEngine, Snapshot, SoftwareEngine, XlaSnapshot,
};
use teda_fpga::ensemble::EnsembleEngine;
use teda_fpga::persist::{codec, CheckpointStore, FileStore};
use teda_fpga::stream::Sample;
use teda_fpga::util::benchkit::{black_box, Bench};
use teda_fpga::util::prng::SplitMix64;

/// Samples folded into each benchmarked snapshot.
const WARM_SAMPLES: u64 = 1_000;

fn feed(engine: &mut dyn Engine, sid: u64) -> StateCheckpoint {
    let mut rng = SplitMix64::new(sid ^ 0x7EDA);
    for seq in 0..WARM_SAMPLES {
        engine
            .ingest(&Sample {
                stream_id: sid,
                seq,
                values: vec![rng.normal(), rng.normal()],
            })
            .unwrap();
    }
    StateCheckpoint {
        stream_id: sid,
        seq: WARM_SAMPLES - 1,
        snapshot: engine.snapshot(sid).unwrap(),
    }
}

/// `(label, checkpoint, fresh-engine constructor for restore timing)`.
type Case = (
    &'static str,
    StateCheckpoint,
    Option<Box<dyn Fn() -> Box<dyn Engine>>>,
);

fn cases() -> Vec<Case> {
    let ens_cfg = EnsembleConfig::from_member_list(
        "teda:m=3+rtl:m=1.5+msigma:m=3+zscore:m=3,w=64",
        CombinerKind::Adaptive,
    )
    .unwrap();
    let ens_cfg2 = ens_cfg.clone();
    vec![
        (
            "software",
            feed(&mut SoftwareEngine::new(2, 3.0), 1),
            Some(Box::new(|| {
                Box::new(SoftwareEngine::new(2, 3.0)) as Box<dyn Engine>
            })),
        ),
        (
            "rtl",
            feed(&mut RtlEngine::new(2, 3.0), 2),
            Some(Box::new(|| {
                Box::new(RtlEngine::new(2, 3.0)) as Box<dyn Engine>
            })),
        ),
        (
            "ensemble",
            feed(&mut EnsembleEngine::new(&ens_cfg, 2).unwrap(), 3),
            Some(Box::new(move || {
                Box::new(EnsembleEngine::new(&ens_cfg2, 2).unwrap())
                    as Box<dyn Engine>
            })),
        ),
        (
            "xla(codec-only)",
            StateCheckpoint {
                stream_id: 4,
                seq: WARM_SAMPLES - 1,
                snapshot: Snapshot::Xla(XlaSnapshot {
                    mu: vec![0.1, -0.1],
                    var: 0.5,
                    k: 960.0,
                    m: 3.0,
                    // One queued chunk + a partial buffer, the typical
                    // mid-stream shape for a (T=32, N=2) variant.
                    chunks: vec![(960, vec![0.25; 64])],
                    buf: vec![0.5; 16],
                    seq_base: 992,
                }),
                // No engine restore without artifacts.
            },
            None,
        ),
    ]
}

fn main() {
    println!(
        "== checkpoint persistence (snapshot after {WARM_SAMPLES} samples, \
         N=2) ==\n"
    );
    let mut results = Vec::new();
    for (label, cp, make_engine) in cases() {
        let encoded = codec::encode(&cp);
        let bytes = encoded.len();

        let enc = Bench::new(format!("encode_{label}"))
            .iters(200)
            .run(|| {
                black_box(codec::encode(black_box(&cp)));
            });
        let dec = Bench::new(format!("decode_{label}"))
            .iters(200)
            .run(|| {
                black_box(codec::decode(black_box(&encoded)).unwrap());
            });
        let restore_ns = make_engine.map(|make| {
            let report = Bench::new(format!("restore_{label}"))
                .iters(100)
                .run(|| {
                    let mut eng = make();
                    let decoded =
                        codec::decode(black_box(&encoded)).unwrap();
                    eng.restore(decoded.stream_id, decoded.snapshot)
                        .unwrap();
                    black_box(eng.active_streams());
                });
            report.mean.as_nanos() as f64
        });

        println!(
            "{label:<16} {bytes:>6} B  encode {:>8.0} ns  decode {:>8.0} \
             ns  decode+restore {}",
            enc.mean.as_nanos() as f64,
            dec.mean.as_nanos() as f64,
            match restore_ns {
                Some(ns) => format!("{ns:>8.0} ns"),
                None => "      n/a".into(),
            }
        );

        let mut row = BTreeMap::new();
        row.insert("engine".to_string(), Json::Str(label.to_string()));
        row.insert("snapshot_bytes".to_string(), Json::Num(bytes as f64));
        row.insert(
            "encode_ns".to_string(),
            Json::Num((enc.mean.as_nanos() as f64 * 10.0).round() / 10.0),
        );
        row.insert(
            "decode_ns".to_string(),
            Json::Num((dec.mean.as_nanos() as f64 * 10.0).round() / 10.0),
        );
        row.insert(
            "decode_restore_ns".to_string(),
            match restore_ns {
                Some(ns) => Json::Num((ns * 10.0).round() / 10.0),
                None => Json::Null,
            },
        );
        results.push(Json::Obj(row));
    }

    // Durable round trip: FileStore put (encode + temp write + rename +
    // retention) and latest (scan + read + decode) for a software
    // checkpoint — the cold-start restore latency a recovery pays per
    // stream.
    let cp = feed(&mut SoftwareEngine::new(2, 3.0), 9);
    let root = teda_fpga::util::unique_temp_dir("bench-persist");
    let store = FileStore::open(&root, 4).unwrap();
    let put = Bench::new("file_put").iters(200).run(|| {
        store.put(black_box(&cp)).unwrap();
    });
    let get = Bench::new("file_latest").iters(200).run(|| {
        black_box(store.latest(cp.stream_id).unwrap().unwrap());
    });
    std::fs::remove_dir_all(&root).unwrap();
    println!(
        "file store       put {:>8.0} ns  latest {:>8.0} ns",
        put.mean.as_nanos() as f64,
        get.mean.as_nanos() as f64
    );
    let mut row = BTreeMap::new();
    row.insert("engine".to_string(), Json::Str("file-store".to_string()));
    row.insert(
        "put_ns".to_string(),
        Json::Num((put.mean.as_nanos() as f64 * 10.0).round() / 10.0),
    );
    row.insert(
        "latest_ns".to_string(),
        Json::Num((get.mean.as_nanos() as f64 * 10.0).round() / 10.0),
    );
    results.push(Json::Obj(row));

    let mut doc = BTreeMap::new();
    doc.insert("bench".to_string(), Json::Str("persist".to_string()));
    doc.insert(
        "workload".to_string(),
        Json::Str(format!(
            "one stream checkpointed after {WARM_SAMPLES} samples, N=2; \
             ensemble = teda+rtl+msigma+zscore(adaptive)"
        )),
    );
    doc.insert("results".to_string(), Json::Arr(results));
    let json = Json::Obj(doc).to_string_compact();

    // Always the repository root (one level above the cargo manifest),
    // matching the other BENCH_*.json emitters.
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("cargo manifest dir has a parent")
        .join("BENCH_persist.json");
    std::fs::write(&path, json + "\n").expect("write BENCH_persist.json");
    println!("wrote {}", path.display());
}

//! Property-based integration: the cycle-accurate RTL pipeline must be
//! BIT-EXACT (f32) with the software TEDA oracle on arbitrary streams,
//! and must reproduce the DAMADICS fault detections end-to-end.

use teda_fpga::damadics::{actuator1_schedule, ActuatorSim};
use teda_fpga::rtl::TedaRtl;
use teda_fpga::teda::TedaState;
use teda_fpga::util::propkit::forall;

#[test]
fn prop_rtl_bitexact_with_software_f32() {
    forall("rtl == software f32", 40, |g| {
        let n = g.usize_in(1, 5);
        let len = g.usize_in(3, 200);
        let m = g.f64_in(0.5, 5.0) as f32;
        let samples: Vec<Vec<f32>> = (0..len)
            .map(|_| {
                (0..n).map(|_| g.f64_in(-10.0, 10.0) as f32).collect()
            })
            .collect();
        let mut rtl = TedaRtl::new(n, m).unwrap();
        let mut sw = TedaState::<f32>::new(n);
        let verdicts = rtl.run(&samples).unwrap();
        assert_eq!(verdicts.len(), len);
        for (i, v) in verdicts.iter().enumerate() {
            let step = sw.step(&samples[i], m);
            assert_eq!(v.k, (i + 1) as u64);
            assert_eq!(v.outlier, step.outlier, "outlier k={}", v.k);
            if v.k >= 2 && sw.var > 0.0 {
                assert_eq!(
                    v.eccentricity.to_bits(),
                    step.eccentricity.to_bits(),
                    "ecc k={} n={n} m={m}",
                    v.k
                );
                assert_eq!(v.zeta.to_bits(), step.zeta.to_bits());
                assert_eq!(v.threshold.to_bits(), step.threshold.to_bits());
                assert_eq!(v.variance.to_bits(), sw.var.to_bits());
            }
        }
    });
}

#[test]
fn prop_rtl_constant_streams_match_software_exactly() {
    // Constant streams are the fp-degenerate regime (σ² is rounding
    // noise — see teda::state's identical-samples test): the RTL and the
    // f32 software reference must still agree flag-for-flag, because
    // they execute the identical IEEE datapath.
    forall("constant stream rtl == sw", 16, |g| {
        let n = g.usize_in(1, 4);
        let val: Vec<f32> =
            (0..n).map(|_| g.f64_in(-3.0, 3.0) as f32).collect();
        let samples: Vec<Vec<f32>> = (0..64).map(|_| val.clone()).collect();
        let mut rtl = TedaRtl::new(n, 3.0).unwrap();
        let mut sw = TedaState::<f32>::new(n);
        for v in rtl.run(&samples).unwrap() {
            let step = sw.step(&val, 3.0);
            // Software applies Eq. 1's σ² > 0 guard; the RTL divider sees
            // the same σ². When σ² == 0 exactly both emit "not outlier";
            // when σ² is rounding noise both datapaths flag identically.
            assert_eq!(v.outlier, step.outlier, "k={}", v.k);
        }
    });
}

#[test]
fn rtl_detects_damadics_faults_like_software() {
    // End-to-end on the paper's validation data: the hardware pipeline
    // must catch the same Table 2 faults as the f32 software detector.
    for event in actuator1_schedule().into_iter().take(3) {
        let trace = ActuatorSim::with_seed(2001).generate_day(Some(&event));
        let mut rtl = TedaRtl::new(2, 3.0).unwrap();
        let mut sw = TedaState::<f32>::new(2);
        let mut rtl_hits = 0u32;
        let mut sw_hits = 0u32;
        let samples32: Vec<Vec<f32>> = trace
            .samples
            .iter()
            .map(|s| s.iter().map(|&v| v as f32).collect())
            .collect();
        let verdicts = rtl.run(&samples32).unwrap();
        for (i, v) in verdicts.iter().enumerate() {
            let step = sw.step(&samples32[i], 3.0);
            assert_eq!(v.outlier, step.outlier, "k={}", v.k);
            if event.contains(i) {
                rtl_hits += v.outlier as u32;
                sw_hits += step.outlier as u32;
            }
        }
        assert!(rtl_hits > 0, "item {}: RTL missed the fault", event.item);
        assert_eq!(rtl_hits, sw_hits);
    }
}

#[test]
fn prop_pipeline_initial_delay_matches_eq7() {
    // The first verdict must appear exactly at the 3rd clock (d = 3·t_c)
    // regardless of stream shape.
    forall("eq7 latency", 12, |g| {
        let n = g.usize_in(1, 4);
        let mut rtl = TedaRtl::new(n, 3.0).unwrap();
        let x: Vec<f32> = (0..n).map(|_| g.f64_in(0.0, 1.0) as f32).collect();
        assert!(rtl.clock(&x).unwrap().is_none());
        assert!(rtl.clock(&x).unwrap().is_none());
        assert!(rtl.clock(&x).unwrap().is_some());
    });
}

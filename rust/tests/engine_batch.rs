//! Batch-native engine kernels: `Engine::process_batch` must be
//! BIT-identical to per-sample `Engine::ingest` — same verdicts, same
//! float bit patterns (ζ, threshold, eccentricity compared via
//! `to_bits`, which also pins the RTL pipeline's NaN ζ₁) — for every
//! backend, under every burst split.
//!
//! Also pins the worker-level eviction clock: the run-coalesced batched
//! submit path must tick the idle-eviction clock once per SAMPLE (not
//! once per burst), evicting the same streams at the same points as
//! per-sample submission.

use std::collections::BTreeMap;

use teda_fpga::config::{EngineKind, EnsembleConfig, ServiceConfig};
use teda_fpga::coordinator::Service;
use teda_fpga::engine::{
    Engine, EngineVerdict, RtlEngine, SoftwareEngine, XlaEngine,
};
use teda_fpga::ensemble::EnsembleEngine;
use teda_fpga::runtime::XlaRuntime;
use teda_fpga::stream::Sample;
use teda_fpga::util::prng::SplitMix64;

type VerdictMap = BTreeMap<(u64, u64), EngineVerdict>;

/// Everything a verdict asserts, bit-exact (floats compared by bits,
/// NaN-safe).
fn key_fields(v: &EngineVerdict) -> (u64, bool, u64, u64, u64) {
    (
        v.k,
        v.outlier,
        v.zeta.to_bits(),
        v.threshold.to_bits(),
        v.eccentricity.to_bits(),
    )
}

fn index(verdicts: Vec<EngineVerdict>) -> VerdictMap {
    let mut map = VerdictMap::new();
    for v in verdicts {
        let key = (v.stream_id, v.seq);
        assert!(map.insert(key, v).is_none(), "duplicate verdict {key:?}");
    }
    map
}

/// A burst with randomized run structure: runs of 1..=24 consecutive
/// samples per stream, streams revisited in random order, per-stream
/// seqs monotone — the shape the worker's coalescer actually sees.
fn ragged_burst(streams: u64, total: usize, seed: u64) -> Vec<Sample> {
    let mut rng = SplitMix64::new(seed);
    let mut seqs = vec![0u64; streams as usize];
    let mut out = Vec::with_capacity(total);
    while out.len() < total {
        let sid = rng.below(streams);
        let run_len = (1 + rng.below(24)) as usize;
        for _ in 0..run_len.min(total - out.len()) {
            let seq = &mut seqs[sid as usize];
            out.push(Sample {
                stream_id: sid,
                seq: *seq,
                values: vec![rng.uniform(-2.0, 2.0), rng.uniform(-2.0, 2.0)],
            });
            *seq += 1;
        }
    }
    out
}

/// Oracle: the per-sample path, one `ingest` per sample, then flush.
fn run_single(eng: &mut dyn Engine, samples: &[Sample]) -> VerdictMap {
    let mut out = Vec::new();
    for s in samples {
        out.extend(eng.ingest(s).unwrap());
    }
    out.extend(eng.flush().unwrap());
    index(out)
}

/// Subject: the same samples through `process_batch`, split at random
/// points (split sizes 1..=full burst — runs land split across calls).
fn run_batched(
    eng: &mut dyn Engine,
    samples: &[Sample],
    split_seed: u64,
) -> VerdictMap {
    let mut rng = SplitMix64::new(split_seed);
    let mut out = Vec::new();
    let mut off = 0;
    while off < samples.len() {
        let len = (1 + rng.below(97)) as usize;
        let end = (off + len).min(samples.len());
        eng.process_batch(&samples[off..end], &mut out).unwrap();
        off = end;
    }
    out.extend(eng.flush().unwrap());
    index(out)
}

fn assert_bit_identical(single: &VerdictMap, batched: &VerdictMap) {
    assert_eq!(single.len(), batched.len(), "verdict count diverged");
    for (key, a) in single {
        let b = batched
            .get(key)
            .unwrap_or_else(|| panic!("verdict missing at {key:?}"));
        assert_eq!(key_fields(a), key_fields(b), "bits diverged at {key:?}");
    }
}

/// Property: for several random workloads and several random burst
/// splits, batch ≡ single bit-exactly.
fn check_engine(mut make: impl FnMut() -> Box<dyn Engine>) {
    for workload_seed in [1u64, 42, 0xBEEF] {
        let samples = ragged_burst(6, 600, workload_seed);
        let single = run_single(make().as_mut(), &samples);
        assert_eq!(single.len(), samples.len(), "oracle lost verdicts");
        for split_seed in [7u64, 1000003, u64::MAX / 3] {
            let batched = run_batched(make().as_mut(), &samples, split_seed);
            assert_bit_identical(&single, &batched);
        }
        // Degenerate splits: the whole burst at once, and one
        // maximal-length run per stream (pure coalesced case).
        let mut out = Vec::new();
        let mut eng = make();
        eng.process_batch(&samples, &mut out).unwrap();
        out.extend(eng.flush().unwrap());
        assert_bit_identical(&single, &index(out));
    }
}

#[test]
fn software_batch_is_bit_identical() {
    check_engine(|| Box::new(SoftwareEngine::new(2, 3.0)));
}

#[test]
fn rtl_batch_is_bit_identical() {
    check_engine(|| Box::new(RtlEngine::new(2, 3.0)));
}

#[test]
fn ensemble_batch_is_bit_identical() {
    let cfg = EnsembleConfig::default();
    check_engine(|| Box::new(EnsembleEngine::new(&cfg, 2).unwrap()));
}

#[test]
fn xla_batch_is_bit_identical() {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !std::path::Path::new(dir).join("manifest.json").exists() {
        eprintln!("artifacts missing; skipping XLA batch identity test");
        return;
    }
    let rt = XlaRuntime::new(dir).unwrap();
    check_engine(|| Box::new(XlaEngine::new(&rt, 2, 1).unwrap()));
}

/// The batched path must surface the same error at the same sample as
/// the per-sample path, with the same verdicts already emitted: samples
/// before the bad one are folded in, samples after it are not. (The RTL
/// pipeline dim-checks every clock; the software engine never errors.)
#[test]
fn batch_errors_match_per_sample_errors() {
    let good = |seq: u64| Sample {
        stream_id: 3,
        seq,
        values: vec![0.5, 0.25 * seq as f64],
    };
    let bad = Sample { stream_id: 3, seq: 5, values: vec![1.0] }; // dim 1
    let feed =
        vec![good(0), good(1), good(2), good(3), good(4), bad, good(6)];
    let mut single = RtlEngine::new(2, 3.0);
    let mut got_single = Vec::new();
    let mut err_at = None;
    for (i, s) in feed.iter().enumerate() {
        match single.ingest(s) {
            Ok(v) => got_single.extend(v),
            Err(_) => {
                err_at = Some(i);
                break;
            }
        }
    }
    assert_eq!(err_at, Some(5), "oracle must hit the dim error");
    let mut batched = RtlEngine::new(2, 3.0);
    let mut got_batched = Vec::new();
    assert!(
        batched.process_batch(&feed, &mut got_batched).is_err(),
        "batched path must surface the dim error"
    );
    assert_eq!(got_single.len(), got_batched.len());
    for (a, b) in got_single.iter().zip(&got_batched) {
        assert_eq!(key_fields(a), key_fields(b), "pre-error verdicts");
    }
}

/// Worker-level regression: the run-coalesced batched path ticks the
/// idle-eviction clock once per sample, so streams are evicted at the
/// SAME points as per-sample submission — same eviction count, and the
/// re-appearing stream restarts at k = 1 with bit-identical verdicts.
#[test]
fn batched_eviction_clock_matches_single() {
    const EVICT_AFTER: u64 = 40;
    let sample = |sid: u64, seq: u64| {
        let mut rng = SplitMix64::new(sid.wrapping_mul(0x9E37) ^ seq);
        Sample {
            stream_id: sid,
            seq,
            values: vec![rng.uniform(-2.0, 2.0), rng.uniform(-2.0, 2.0)],
        }
    };
    // Phase A: streams 0 and 1 interleave. Phase B: stream 0 alone long
    // enough that stream 1 goes idle past the eviction horizon inside a
    // burst. Phase C: stream 1 returns and must restart fresh at k = 1.
    let mut feed = Vec::new();
    for seq in 0..20u64 {
        feed.push(sample(0, seq));
        feed.push(sample(1, seq));
    }
    for seq in 20..120u64 {
        feed.push(sample(0, seq));
    }
    for seq in 20..40u64 {
        feed.push(sample(1, seq));
    }
    let run = |batched: bool| {
        let svc = Service::start(ServiceConfig {
            engine: EngineKind::Software,
            workers: 1,
            n_features: 2,
            evict_after: EVICT_AFTER,
            ..Default::default()
        })
        .unwrap();
        if batched {
            // Bursts of 17 misalign with the eviction horizon, so scans
            // must fire mid-burst, mid-run, exactly at tick multiples.
            for chunk in feed.chunks(17) {
                svc.submit_batch(chunk.to_vec()).unwrap();
            }
        } else {
            for s in &feed {
                svc.submit(s.clone()).unwrap();
            }
        }
        let m = svc.metrics();
        let out = svc.finish().unwrap();
        (m.stream_evictions.get(), out)
    };
    let (evict_single, out_single) = run(false);
    let (evict_batched, out_batched) = run(true);
    assert!(evict_single >= 1, "workload must trigger at least one eviction");
    assert_eq!(
        evict_single, evict_batched,
        "eviction clock diverged between batched and single paths"
    );
    assert_eq!(out_single.len(), out_batched.len());
    let map_single: VerdictMap =
        index(out_single.into_iter().map(|c| c.verdict).collect());
    let map_batched: VerdictMap =
        index(out_batched.into_iter().map(|c| c.verdict).collect());
    assert_bit_identical(&map_single, &map_batched);
    // The evicted stream really did restart: its first post-idle
    // verdict is k = 1 despite seq = 20.
    assert_eq!(map_single[&(1, 20)].k, 1, "stream 1 was not evicted");
}

//! End-to-end: the coordinator drives a ≥3-member ensemble exactly like
//! a single backend, and fused detection still catches the paper's
//! DAMADICS faults (the `teda-fpga detect --engine ensemble` path).

use std::collections::BTreeMap;

use teda_fpga::config::{
    CombinerKind, EngineKind, EnsembleConfig, ServiceConfig,
};
use teda_fpga::coordinator::Service;
use teda_fpga::damadics::{evaluate_detection, schedule_item, ActuatorSim};
use teda_fpga::engine::Engine as _;
use teda_fpga::ensemble::EnsembleEngine;
use teda_fpga::stream::Sample;
use teda_fpga::util::prng::SplitMix64;

fn ensemble_cfg(members: &str, workers: usize) -> ServiceConfig {
    ServiceConfig {
        engine: EngineKind::Ensemble,
        workers,
        n_features: 2,
        queue_capacity: 128,
        ensemble: EnsembleConfig::from_member_list(
            members,
            CombinerKind::Majority,
        )
        .unwrap(),
        ..Default::default()
    }
}

#[test]
fn service_drives_three_member_ensemble_exactly_once_per_sample() {
    let svc =
        Service::start(ensemble_cfg("teda+msigma+zscore:m=3,w=32", 3))
            .unwrap();
    let em = svc.ensemble_metrics().expect("per-member counters");
    let mut rng = SplitMix64::new(41);
    let (streams, per_stream) = (8u64, 120u64);
    for seq in 0..per_stream {
        for sid in 0..streams {
            svc.submit(Sample {
                stream_id: sid,
                seq,
                values: vec![rng.normal(), rng.normal()],
            })
            .unwrap();
        }
    }
    let out = svc.finish().unwrap();
    let total = (streams * per_stream) as usize;
    assert_eq!(out.len(), total);

    // Exactly-once per (stream, seq), per-stream order preserved.
    let mut seen: BTreeMap<(u64, u64), bool> = BTreeMap::new();
    let mut last_seq: BTreeMap<u64, u64> = BTreeMap::new();
    for c in &out {
        let v = &c.verdict;
        assert!(
            seen.insert((v.stream_id, v.seq), v.outlier).is_none(),
            "duplicate verdict for {:?}",
            (v.stream_id, v.seq)
        );
        if let Some(&prev) = last_seq.get(&v.stream_id) {
            assert!(v.seq > prev, "stream {} reordered", v.stream_id);
        }
        last_seq.insert(v.stream_id, v.seq);
    }
    assert_eq!(seen.len(), total);

    // Per-member counters agree across all shards combined.
    assert_eq!(em.fused_verdicts.get(), total as u64);
    for m in &em.members {
        assert_eq!(m.votes.get(), total as u64, "member {}", m.label);
    }
}

#[test]
fn mixed_rtl_software_ensemble_in_service() {
    // Heterogeneous latencies (RTL answers two samples late) must not
    // lose or duplicate verdicts through the worker/flush path.
    let svc = Service::start(ensemble_cfg("teda+rtl+msigma", 2)).unwrap();
    for seq in 0..60u64 {
        for sid in 0..4u64 {
            svc.submit(Sample {
                stream_id: sid,
                seq,
                values: vec![seq as f64 * 0.01, 0.4],
            })
            .unwrap();
        }
    }
    let out = svc.finish().unwrap();
    assert_eq!(out.len(), 240);
}

#[test]
fn fused_ensemble_detects_damadics_fault_items() {
    // The detect --engine ensemble path: a 3-member majority ensemble
    // must still catch Table 2 faults with a sane false-alarm budget.
    let ecfg = EnsembleConfig::from_member_list(
        "teda:m=3+msigma:m=3+zscore:m=3,w=64",
        CombinerKind::Majority,
    )
    .unwrap();
    for item in [1u32, 4, 7] {
        let event = schedule_item(item).unwrap();
        let trace =
            ActuatorSim::with_seed(2001).generate_day(Some(&event));
        let mut eng = EnsembleEngine::new(&ecfg, 2).unwrap();
        let mut flags = vec![false; trace.samples.len()];
        for (seq, values) in trace.samples.iter().enumerate() {
            for v in eng
                .ingest(&Sample {
                    stream_id: 0,
                    seq: seq as u64,
                    values: values.clone(),
                })
                .unwrap()
            {
                flags[v.seq as usize] = v.outlier;
            }
        }
        for v in eng.flush().unwrap() {
            flags[v.seq as usize] = v.outlier;
        }
        let report = evaluate_detection(&flags, &event, 1000);
        assert!(report.detected(), "item {item} not detected by ensemble");
        assert!(
            report.false_alarm_rate() < 0.05,
            "item {item}: far {}",
            report.false_alarm_rate()
        );
    }
}

#[test]
fn any_of_ensemble_is_at_least_as_sensitive_as_single_teda() {
    let event = schedule_item(2).unwrap();
    let trace = ActuatorSim::with_seed(2001).generate_day(Some(&event));

    let mut single = teda_fpga::teda::TedaDetector::new(2, 3.0);
    let single_flags: Vec<bool> =
        trace.samples.iter().map(|s| single.step(s).outlier).collect();
    let single_report = evaluate_detection(&single_flags, &event, 1000);

    let ecfg = EnsembleConfig::from_member_list(
        "teda:m=3+msigma:m=3+zscore:m=3,w=64",
        CombinerKind::AnyOf,
    )
    .unwrap();
    let mut eng = EnsembleEngine::new(&ecfg, 2).unwrap();
    let mut fused = vec![false; trace.samples.len()];
    for (seq, values) in trace.samples.iter().enumerate() {
        for v in eng
            .ingest(&Sample {
                stream_id: 0,
                seq: seq as u64,
                values: values.clone(),
            })
            .unwrap()
        {
            fused[v.seq as usize] = v.outlier;
        }
    }
    for v in eng.flush().unwrap() {
        fused[v.seq as usize] = v.outlier;
    }
    let fused_report = evaluate_detection(&fused, &event, 1000);

    // Any-of contains the TEDA member, so it can only detect earlier
    // (or equally) and hit at least as many window samples.
    assert!(fused_report.detected());
    assert!(
        fused_report.hits_in_window >= single_report.hits_in_window,
        "any-of lost window hits: {} < {}",
        fused_report.hits_in_window,
        single_report.hits_in_window
    );
    if let (Some(fl), Some(sl)) =
        (fused_report.latency, single_report.latency)
    {
        assert!(fl <= sl, "any-of later than single: {fl} > {sl}");
    }
}

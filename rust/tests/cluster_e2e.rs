//! Cluster end-to-end: several node processes' worth of machinery —
//! full node cores, the cluster control plane, and the framed
//! transport — serving ONE logical shard map, attacked the same way
//! `rebalance_e2e` attacks a single process:
//!
//! - shards migrate **between nodes** mid-stream (seal → adopt over
//!   the wire, sealed bundles in persist-codec records) and verdicts
//!   must stay bit-identical to an undisturbed single-service run;
//! - a whole node is killed mid-stream (transport torn down, node core
//!   aborted, unsealed state gone) and a peer fails over from the
//!   shared checkpoint store — the union of verdicts must STILL be
//!   bit-identical, for the software, RTL, and ensemble engines;
//! - heartbeat monitoring performs that failover automatically;
//! - a third node joins **mid-stream** (`--join`), pulls its uniform
//!   share via seal → adopt, and the verdict stream stays
//!   bit-identical; killing the joiner later fails its shards back;
//! - a burst submitted **while a node is dead** (before failover
//!   runs) parks in the [`ClusterHandle`] ingest buffer and replays
//!   once the survivor adopts — no lost verdicts, no contradictory
//!   duplicates.
//!
//! Nodes here live in one test process but share nothing except the
//! checkpoint store and their sockets — the same isolation a real
//! multi-process deployment has (the CI smoke runs the true
//! two-process version).

use std::collections::BTreeMap;
use std::sync::Arc;

use teda_fpga::config::{
    ClusterConfig, CombinerKind, EngineKind, EnsembleConfig,
    ServiceConfig, ShardingConfig,
};
use teda_fpga::coordinator::transport::frame::Msg;
use teda_fpga::coordinator::transport::net::{PeerAddr, RpcClient};
use teda_fpga::coordinator::{ClusterNode, Service, StateManager};
use teda_fpga::engine::EngineVerdict;
use teda_fpga::persist::{CheckpointStore, MemoryStore};
use teda_fpga::stream::Sample;
use teda_fpga::util::prng::SplitMix64;

const STREAMS: u64 = 6;
const PER_STREAM: u64 = 90;
const VIRTUAL_SHARDS: u32 = 32;
/// Push shards node 1 → node 2 after this seq...
const MIGRATE_AT: u64 = 30;
/// ...and pull some back after this one.
const PULL_AT: u64 = 60;
/// Whole-node kill point for the failover tests.
const KILL_AT: u64 = 45;

fn cfg(engine: EngineKind) -> ServiceConfig {
    ServiceConfig {
        engine,
        workers: 2,
        n_features: 2,
        queue_capacity: 256,
        sharding: ShardingConfig {
            virtual_shards: VIRTUAL_SHARDS,
            ..Default::default()
        },
        // Same roster as rebalance_e2e: the RTL member's tighter
        // threshold keeps fusion quorums open across every handoff.
        ensemble: EnsembleConfig::from_member_list(
            "teda:m=3+rtl:m=1.5",
            CombinerKind::Adaptive,
        )
        .unwrap(),
        ..Default::default()
    }
}

/// Deterministic per-(stream, seq) sample — identical to the
/// rebalance_e2e generator so runs are comparable across topologies.
fn sample(sid: u64, seq: u64) -> Sample {
    let mut rng = SplitMix64::new(sid.wrapping_mul(0x9E37) ^ seq);
    Sample {
        stream_id: sid,
        seq,
        values: vec![rng.uniform(-2.0, 2.0), rng.uniform(-2.0, 2.0)],
    }
}

fn index(
    out: Vec<teda_fpga::coordinator::Classified>,
    map: &mut BTreeMap<(u64, u64), EngineVerdict>,
) {
    for c in out {
        let key = (c.verdict.stream_id, c.verdict.seq);
        match map.get(&key) {
            // Duplicates must be identical re-derivations (NaN-safe).
            Some(prev) => {
                assert_eq!(prev.k, c.verdict.k, "{key:?}");
                assert_eq!(prev.outlier, c.verdict.outlier, "{key:?}");
                assert_eq!(
                    prev.zeta.to_bits(),
                    c.verdict.zeta.to_bits(),
                    "replayed verdict diverged at {key:?}"
                );
            }
            None => {
                map.insert(key, c.verdict);
            }
        }
    }
}

fn reference(engine: EngineKind) -> BTreeMap<(u64, u64), EngineVerdict> {
    let svc = Service::start(cfg(engine)).unwrap();
    for seq in 0..PER_STREAM {
        for sid in 0..STREAMS {
            svc.submit(sample(sid, seq)).unwrap();
        }
    }
    let mut map = BTreeMap::new();
    index(svc.finish().unwrap(), &mut map);
    map
}

fn assert_bit_identical(
    engine: EngineKind,
    full: &BTreeMap<(u64, u64), EngineVerdict>,
    got: &BTreeMap<(u64, u64), EngineVerdict>,
) {
    assert_eq!(
        full.len(),
        (STREAMS * PER_STREAM) as usize,
        "{engine}: reference must classify everything"
    );
    assert_eq!(
        got.len(),
        full.len(),
        "{engine}: cluster run lost or duplicated verdicts"
    );
    for (key, a) in full {
        let b = &got[key];
        assert_eq!(a.k, b.k, "{engine} {key:?}");
        assert_eq!(a.outlier, b.outlier, "{engine} {key:?}");
        assert_eq!(
            a.zeta.to_bits(),
            b.zeta.to_bits(),
            "{engine} {key:?}: zeta {} vs {}",
            a.zeta,
            b.zeta
        );
        assert_eq!(a.threshold.to_bits(), b.threshold.to_bits());
    }
}

/// Two cluster configs wired at each other over unix sockets in a
/// fresh temp dir (deterministic addresses — no port races under
/// parallel `cargo test`).
fn uds_pair(tag: &str) -> (ClusterConfig, ClusterConfig) {
    let dir = teda_fpga::util::unique_temp_dir(&format!("cluster-{tag}"));
    std::fs::create_dir_all(&dir).unwrap();
    let a = format!("unix:{}", dir.join("node1.sock").display());
    let b = format!("unix:{}", dir.join("node2.sock").display());
    (
        ClusterConfig {
            node_id: 1,
            listen: Some(a.clone()),
            peers: vec![format!("2={b}")],
            heartbeat_ms: 50,
            failover_ms: 0,
            ..Default::default()
        },
        ClusterConfig {
            node_id: 2,
            listen: Some(b),
            peers: vec![format!("1={a}")],
            heartbeat_ms: 50,
            failover_ms: 0,
            ..Default::default()
        },
    )
}

/// The two-node pair plus a third config that *joins dynamically*:
/// no static roster — node 3 knows only node 1's address and learns
/// everything else from the `JoinOk`.
fn uds_trio(
    tag: &str,
) -> (ClusterConfig, ClusterConfig, ClusterConfig) {
    let dir = teda_fpga::util::unique_temp_dir(&format!("cluster-{tag}"));
    std::fs::create_dir_all(&dir).unwrap();
    let a = format!("unix:{}", dir.join("node1.sock").display());
    let b = format!("unix:{}", dir.join("node2.sock").display());
    let c = format!("unix:{}", dir.join("node3.sock").display());
    (
        ClusterConfig {
            node_id: 1,
            listen: Some(a.clone()),
            peers: vec![format!("2={b}")],
            heartbeat_ms: 50,
            failover_ms: 0,
            ..Default::default()
        },
        ClusterConfig {
            node_id: 2,
            listen: Some(b),
            peers: vec![format!("1={a}")],
            heartbeat_ms: 50,
            failover_ms: 0,
            ..Default::default()
        },
        ClusterConfig {
            node_id: 3,
            listen: Some(c),
            peers: vec![],
            join: Some(a),
            heartbeat_ms: 50,
            failover_ms: 0,
            ..Default::default()
        },
    )
}

/// Node with a service wired to a (possibly shared) checkpoint store.
fn start_node(
    engine: EngineKind,
    ccfg: &ClusterConfig,
    store: Option<Arc<MemoryStore>>,
) -> (Arc<Service>, ClusterNode) {
    let mut scfg = cfg(engine);
    let svc = match store {
        Some(store) => {
            scfg.checkpoint_every = 10;
            scfg.restore_on_resume = true;
            let mgr = Arc::new(StateManager::with_store(store));
            Arc::new(Service::start_with_state(scfg, mgr).unwrap())
        }
        None => Arc::new(Service::start(scfg).unwrap()),
    };
    let node = ClusterNode::start(svc.clone(), ccfg).unwrap();
    (svc, node)
}

/// Clean teardown: control plane first, then the node core — the
/// verdicts drained from `finish` join the caller's map.
fn finish_node(
    svc: Arc<Service>,
    node: ClusterNode,
    map: &mut BTreeMap<(u64, u64), EngineVerdict>,
) {
    node.shutdown().unwrap();
    let svc = Arc::try_unwrap(svc)
        .unwrap_or_else(|_| panic!("service still shared at teardown"));
    index(svc.finish().unwrap(), map);
}

/// Mid-stream node → node migration (push AND pull) must be invisible
/// in the verdict stream.
fn assert_cluster_migration_invisible(engine: EngineKind) {
    let full = reference(engine);
    let (c1, c2) = uds_pair(&format!("mig-{engine}"));
    let (svc1, n1) = start_node(engine, &c1, None);
    let (svc2, n2) = start_node(engine, &c2, None);
    assert_eq!(n1.hello_peers(), 1, "node 2 must answer hello");
    assert_eq!(n2.hello_peers(), 1, "node 1 must answer hello");
    // Epoch-0 agreement needs no handshake: both nodes computed the
    // same deterministic round-robin table.
    assert_eq!(n1.table(), n2.table());
    assert_eq!(
        n1.owned_shards().len() + n2.owned_shards().len(),
        VIRTUAL_SHARDS as usize
    );

    // All traffic enters through node 1; samples for node-2 shards
    // cross the wire as Samples frames.
    let ingest = n1.handle();
    for seq in 0..PER_STREAM {
        let burst: Vec<Sample> =
            (0..STREAMS).map(|sid| sample(sid, seq)).collect();
        ingest.submit_batch(burst).unwrap();
        if seq == MIGRATE_AT {
            let moved: Vec<u32> =
                n1.owned_shards().into_iter().take(6).collect();
            let stats = n1.migrate_to_peer(2, &moved).unwrap();
            assert!(stats.streams > 0, "seal must ship real state");
            assert!(stats.bytes > 0);
            assert_eq!(n1.epoch(), 1, "push bumps the epoch");
            assert_eq!(
                n1.table(),
                n2.table(),
                "table push must reach the peer synchronously"
            );
            for s in &moved {
                assert_eq!(n2.table().owner_of(*s), 2);
            }
        }
        if seq == PULL_AT {
            let back: Vec<u32> =
                n1.table().shards_of(2).into_iter().take(4).collect();
            n1.pull_from_peer(2, &back).unwrap();
            assert_eq!(n1.epoch(), 2, "pull bumps the epoch");
            assert_eq!(n1.table(), n2.table());
            for s in &back {
                assert_eq!(n1.table().owner_of(*s), 1);
            }
        }
    }
    let m1 = svc1.metrics();
    let m2 = svc2.metrics();
    drop(ingest);
    let mut got = BTreeMap::new();
    finish_node(svc1, n1, &mut got);
    finish_node(svc2, n2, &mut got);

    assert_bit_identical(engine, &full, &got);
    assert!(m1.bundle_bytes_rx.get() > 0, "pull shipped bundles back");
    assert!(m2.bundle_bytes_rx.get() > 0, "push shipped bundles over");
    assert!(
        m1.samples_forwarded.get() > 0,
        "node 1 must have forwarded node-2 samples"
    );
    assert!(m1.peer_connects.get() >= 1);
    assert!(m1.heartbeats_rx.get() + m2.heartbeats_rx.get() > 0);
}

#[test]
fn software_cross_node_migration_is_invisible() {
    assert_cluster_migration_invisible(EngineKind::Software);
}

#[test]
fn rtl_cross_node_migration_is_invisible() {
    // In-flight pipeline verdicts must cross the WIRE inside the
    // register-file snapshot and re-emerge on the other node.
    assert_cluster_migration_invisible(EngineKind::Rtl);
}

#[test]
fn ensemble_cross_node_migration_is_invisible() {
    assert_cluster_migration_invisible(EngineKind::Ensemble);
}

/// Kill a whole node mid-stream; a peer adopts its shards from the
/// shared checkpoint store; re-fed samples re-derive identically.
fn assert_kill_and_failover_recovers(engine: EngineKind) {
    let full = reference(engine);
    let store = Arc::new(MemoryStore::new());
    let (c1, c2) = uds_pair(&format!("kill-{engine}"));
    let (svc1, n1) = start_node(engine, &c1, Some(store.clone()));
    let (svc2, n2) = start_node(engine, &c2, Some(store.clone()));
    n2.hello_peers();
    let owned_before = n2.owned_shards().len();
    assert!(owned_before < VIRTUAL_SHARDS as usize);

    // Phase 1: the survivor's handle feeds both nodes.
    let ingest = n2.handle();
    let mut map = BTreeMap::new();
    for seq in 0..KILL_AT {
        let burst: Vec<Sample> =
            (0..STREAMS).map(|sid| sample(sid, seq)).collect();
        ingest.submit_batch(burst).unwrap();
    }

    // Kill node 1 whole: transport down, node core aborted, every
    // unsealed in-memory state lost. Only its periodic checkpoints in
    // the shared store survive — exactly what a SIGKILL leaves behind.
    n1.shutdown().unwrap();
    let svc1 = Arc::try_unwrap(svc1)
        .unwrap_or_else(|_| panic!("node 1 service still shared"));
    index(svc1.abort().unwrap(), &mut map);

    // Node 2 adopts everything the dead node owned.
    let adopted = n2.failover(1).unwrap();
    assert_eq!(
        adopted,
        VIRTUAL_SHARDS as usize - owned_before,
        "failover must adopt exactly the dead node's shards"
    );
    assert_eq!(n2.owned_shards().len(), VIRTUAL_SHARDS as usize);
    assert_eq!(svc2.metrics().failovers.get(), 1);

    // Every stream checkpointed below the kill point; resume from the
    // lowest watermark and re-feed — dedup absorbs the overlap.
    let mut resume = u64::MAX;
    for sid in 0..STREAMS {
        let cp = store
            .latest(sid)
            .unwrap()
            .expect("checkpoint before the kill");
        assert!(cp.seq < KILL_AT);
        resume = resume.min(cp.seq + 1);
    }
    for seq in resume..PER_STREAM {
        let burst: Vec<Sample> =
            (0..STREAMS).map(|sid| sample(sid, seq)).collect();
        ingest.submit_batch(burst).unwrap();
    }
    drop(ingest);
    finish_node(svc2, n2, &mut map);
    assert_bit_identical(engine, &full, &map);
}

#[test]
fn software_node_kill_failover_is_bit_identical() {
    assert_kill_and_failover_recovers(EngineKind::Software);
}

#[test]
fn rtl_node_kill_failover_is_bit_identical() {
    assert_kill_and_failover_recovers(EngineKind::Rtl);
}

#[test]
fn ensemble_node_kill_failover_is_bit_identical() {
    assert_kill_and_failover_recovers(EngineKind::Ensemble);
}

#[test]
fn heartbeat_monitor_fails_over_automatically() {
    let store = Arc::new(MemoryStore::new());
    let (c1, mut c2) = uds_pair("auto");
    // Node 2 (the surviving leader for a dead node 1's shards) runs
    // the monitor with automatic failover armed.
    c2.failover_ms = 400;
    let (svc1, n1) = start_node(EngineKind::Software, &c1, Some(store.clone()));
    let (svc2, n2) = start_node(EngineKind::Software, &c2, Some(store));
    n2.hello_peers();
    let ingest = n2.handle();
    for seq in 0..KILL_AT {
        let burst: Vec<Sample> =
            (0..STREAMS).map(|sid| sample(sid, seq)).collect();
        ingest.submit_batch(burst).unwrap();
    }
    n1.shutdown().unwrap();
    let svc1 = Arc::try_unwrap(svc1)
        .unwrap_or_else(|_| panic!("node 1 service still shared"));
    svc1.abort().unwrap();

    // No manual intervention: the heartbeat monitor must notice the
    // silence and adopt within a few failover windows.
    let deadline = std::time::Instant::now()
        + std::time::Duration::from_secs(10);
    while n2.owned_shards().len() < VIRTUAL_SHARDS as usize {
        assert!(
            std::time::Instant::now() < deadline,
            "automatic failover never fired (owned {}/{})",
            n2.owned_shards().len(),
            VIRTUAL_SHARDS
        );
        std::thread::sleep(std::time::Duration::from_millis(20));
    }
    assert_eq!(svc2.metrics().failovers.get(), 1);
    assert_eq!(svc2.metrics().peers_alive.get(), 0);
    assert!(n2.epoch() > 0, "failover must advance the epoch");

    // The cluster keeps serving: the handle ingests everything locally.
    for seq in KILL_AT..PER_STREAM {
        let burst: Vec<Sample> =
            (0..STREAMS).map(|sid| sample(sid, seq)).collect();
        ingest.submit_batch(burst).unwrap();
    }
    drop(ingest);
    let mut map = BTreeMap::new();
    finish_node(svc2, n2, &mut map);
    assert!(!map.is_empty());
}

#[test]
fn tcp_loopback_cluster_migrates_and_answers_status() {
    // The TCP flavour of the transport (the CI smoke runs it across
    // real processes; fixed high ports keep parallel tests apart).
    let c1 = ClusterConfig {
        node_id: 1,
        listen: Some("127.0.0.1:17461".into()),
        peers: vec!["2=127.0.0.1:17462".into()],
        heartbeat_ms: 50,
        failover_ms: 0,
        ..Default::default()
    };
    let c2 = ClusterConfig {
        node_id: 2,
        listen: Some("127.0.0.1:17462".into()),
        peers: vec!["1=127.0.0.1:17461".into()],
        heartbeat_ms: 50,
        failover_ms: 0,
        ..Default::default()
    };
    let (svc1, n1) = start_node(EngineKind::Software, &c1, None);
    let (svc2, n2) = start_node(EngineKind::Software, &c2, None);
    assert_eq!(n1.hello_peers(), 1);
    let ingest = n1.handle();
    for seq in 0..40u64 {
        let burst: Vec<Sample> =
            (0..STREAMS).map(|sid| sample(sid, seq)).collect();
        ingest.submit_batch(burst).unwrap();
    }
    let moved: Vec<u32> = n1.owned_shards().into_iter().take(4).collect();
    n1.migrate_to_peer(2, &moved).unwrap();
    assert_eq!(n1.table(), n2.table());

    // What `teda-fpga cluster --addr` does: a raw Status probe.
    let probe = RpcClient::new(PeerAddr::parse("127.0.0.1:17461").unwrap());
    match probe.rpc(&Msg::Status).unwrap() {
        Msg::StatusText { text } => {
            assert!(text.contains("node 1"), "{text}");
            assert!(text.contains("epoch 1"), "{text}");
        }
        other => panic!("unexpected {} reply", other.label()),
    }
    drop(ingest);
    let mut map = BTreeMap::new();
    finish_node(svc1, n1, &mut map);
    finish_node(svc2, n2, &mut map);
    assert_eq!(map.len(), (STREAMS * 40) as usize);
}

/// A third node joins MID-STREAM via the dynamic-join path (what
/// `serve --join ADDR` does), pulls its uniform share through the
/// ordinary seal → adopt migration, and the verdict stream stays
/// bit-identical to an undisturbed single-service run.
fn assert_join_mid_stream_invisible(engine: EngineKind) {
    let full = reference(engine);
    let (c1, c2, c3) = uds_trio(&format!("join-{engine}"));
    let (svc1, n1) = start_node(engine, &c1, None);
    let (svc2, n2) = start_node(engine, &c2, None);
    assert_eq!(n1.hello_peers(), 1);
    let ingest = n1.handle();
    for seq in 0..MIGRATE_AT {
        let burst: Vec<Sample> =
            (0..STREAMS).map(|sid| sample(sid, seq)).collect();
        ingest.submit_batch(burst).unwrap();
    }

    // Node 3 has NO static roster: `join` registers it with node 1,
    // which re-broadcasts the table at epoch+1, gossips the join to
    // node 2, and hands back the full member list. After start it is
    // routable but owns nothing.
    let (svc3, n3) = start_node(engine, &c3, None);
    assert!(n3.owned_shards().is_empty(), "a joiner owns nothing yet");
    assert_eq!(n3.epoch(), 1, "admission re-broadcasts at epoch+1");
    assert_eq!(n3.table(), n1.table());
    assert_eq!(n3.table(), n2.table(), "join gossip must reach node 2");

    // Pull the uniform share — 32 shards / 3 members — from the
    // biggest owners; in-flight streams cross inside sealed bundles.
    let pulled = n3.pull_share().unwrap();
    assert_eq!(pulled, (VIRTUAL_SHARDS / 3) as usize);
    assert_eq!(n3.owned_shards().len(), pulled);
    assert_eq!(n1.table(), n3.table());
    assert_eq!(n2.table(), n3.table());
    assert_eq!(
        n1.owned_shards().len() + n2.owned_shards().len() + pulled,
        VIRTUAL_SHARDS as usize
    );

    // Keep streaming through node 1: the joiner's samples now cross
    // the wire like any other member's.
    for seq in MIGRATE_AT..PER_STREAM {
        let burst: Vec<Sample> =
            (0..STREAMS).map(|sid| sample(sid, seq)).collect();
        ingest.submit_batch(burst).unwrap();
    }
    assert!(
        svc3.metrics().bundle_bytes_rx.get() > 0,
        "pull must ship sealed bundles to the joiner"
    );
    assert!(svc1.metrics().member_joins.get() >= 1);
    drop(ingest);
    let mut got = BTreeMap::new();
    finish_node(svc1, n1, &mut got);
    finish_node(svc2, n2, &mut got);
    finish_node(svc3, n3, &mut got);
    assert_bit_identical(engine, &full, &got);
}

#[test]
fn software_join_mid_stream_is_invisible() {
    assert_join_mid_stream_invisible(EngineKind::Software);
}

#[test]
fn ensemble_join_mid_stream_is_invisible() {
    assert_join_mid_stream_invisible(EngineKind::Ensemble);
}

/// Kill the dynamically-joined node mid-stream: a founding member
/// fails over and re-adopts exactly the share the joiner pulled —
/// including shards it had itself donated earlier — and the union of
/// verdicts is still bit-identical.
#[test]
fn joiner_kill_failover_readopts_its_share() {
    let engine = EngineKind::Software;
    let full = reference(engine);
    let store = Arc::new(MemoryStore::new());
    let (c1, c2, c3) = uds_trio("kill-joiner");
    let (svc1, n1) = start_node(engine, &c1, Some(store.clone()));
    let (svc2, n2) = start_node(engine, &c2, Some(store.clone()));
    n1.hello_peers();
    let ingest = n1.handle();
    let mut map = BTreeMap::new();
    for seq in 0..MIGRATE_AT {
        let burst: Vec<Sample> =
            (0..STREAMS).map(|sid| sample(sid, seq)).collect();
        ingest.submit_batch(burst).unwrap();
    }
    let (svc3, n3) = start_node(engine, &c3, Some(store.clone()));
    let pulled = n3.pull_share().unwrap();
    assert_eq!(pulled, (VIRTUAL_SHARDS / 3) as usize);
    for seq in MIGRATE_AT..KILL_AT {
        let burst: Vec<Sample> =
            (0..STREAMS).map(|sid| sample(sid, seq)).collect();
        ingest.submit_batch(burst).unwrap();
    }

    // SIGKILL-equivalent: transport down, unsealed state gone; only
    // the joiner's periodic checkpoints in the shared store survive.
    n3.shutdown().unwrap();
    let svc3 = Arc::try_unwrap(svc3)
        .unwrap_or_else(|_| panic!("node 3 service still shared"));
    index(svc3.abort().unwrap(), &mut map);

    let adopted = n1.failover(3).unwrap();
    assert_eq!(adopted, pulled, "survivor re-adopts the joiner's share");
    assert_eq!(svc1.metrics().failovers.get(), 1);
    assert_eq!(n1.table(), n2.table());

    // Re-feed from the lowest checkpoint watermark; the inclusive
    // dedup absorbs the overlap on every live stream.
    let mut resume = u64::MAX;
    for sid in 0..STREAMS {
        let cp = store
            .latest(sid)
            .unwrap()
            .expect("checkpoint before the kill");
        assert!(cp.seq < KILL_AT);
        resume = resume.min(cp.seq + 1);
    }
    for seq in resume..PER_STREAM {
        let burst: Vec<Sample> =
            (0..STREAMS).map(|sid| sample(sid, seq)).collect();
        ingest.submit_batch(burst).unwrap();
    }
    drop(ingest);
    finish_node(svc1, n1, &mut map);
    finish_node(svc2, n2, &mut map);
    assert_bit_identical(engine, &full, &map);
}

/// A burst submitted while a peer is DOWN — the failover window —
/// must be absorbed by the [`ClusterHandle`] park-and-replay buffer:
/// `submit_batch` keeps returning `Ok`, the undeliverable share
/// queues locally, and once the survivor adopts, the replay yields
/// the full bit-identical verdict set — no lost verdicts, no
/// contradictory duplicates.
#[test]
fn burst_during_failover_window_is_absorbed() {
    let engine = EngineKind::Software;
    let full = reference(engine);
    let store = Arc::new(MemoryStore::new());
    let (c1, c2) = uds_pair("burst");
    let (svc1, n1) = start_node(engine, &c1, Some(store.clone()));
    let (svc2, n2) = start_node(engine, &c2, Some(store.clone()));
    n2.hello_peers();
    let ingest = n2.handle();
    let mut map = BTreeMap::new();
    for seq in 0..KILL_AT {
        let burst: Vec<Sample> =
            (0..STREAMS).map(|sid| sample(sid, seq)).collect();
        ingest.submit_batch(burst).unwrap();
    }
    n1.shutdown().unwrap();
    let svc1 = Arc::try_unwrap(svc1)
        .unwrap_or_else(|_| panic!("node 1 service still shared"));
    index(svc1.abort().unwrap(), &mut map);

    // Node 1 is dead and nobody has failed over yet. Keep submitting
    // the whole remaining stream from every live stream's replay
    // point: locally-owned samples process normally, node-1 samples
    // park — not one submit errors.
    let mut resume = u64::MAX;
    for sid in 0..STREAMS {
        let cp = store
            .latest(sid)
            .unwrap()
            .expect("checkpoint before the kill");
        resume = resume.min(cp.seq + 1);
    }
    for seq in resume..PER_STREAM {
        let burst: Vec<Sample> =
            (0..STREAMS).map(|sid| sample(sid, seq)).collect();
        ingest
            .submit_batch(burst)
            .expect("burst must be absorbed, not refused");
    }
    assert!(
        ingest.parked() > 0,
        "the dead node's share must be parked, not dropped"
    );
    assert!(svc2.metrics().ingest_parked.get() > 0);

    // Failover; the parked backlog replays onto the adopted shards.
    let adopted = n2.failover(1).unwrap();
    assert!(adopted > 0);
    let deadline = std::time::Instant::now()
        + std::time::Duration::from_secs(10);
    while ingest.flush_parked() > 0 {
        assert!(
            std::time::Instant::now() < deadline,
            "park buffer never drained after failover"
        );
        std::thread::sleep(std::time::Duration::from_millis(20));
    }
    drop(ingest);
    finish_node(svc2, n2, &mut map);
    assert_bit_identical(engine, &full, &map);
}

//! Failover end-to-end: a service is killed mid-stream (no flush — the
//! workers abandon their in-flight state exactly like a crashed
//! process), a new service inherits the checkpoint store, streams
//! resume after the last checkpoint watermark, and the union of
//! verdicts must equal an uninterrupted run verdict-for-verdict — for
//! every `EngineKind`, including an ensemble with an RTL member (open
//! fusion quorums) and adaptive per-stream weights.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;

use teda_fpga::config::{
    CombinerKind, EngineKind, EnsembleConfig, ServiceConfig,
};
use teda_fpga::coordinator::Service;
use teda_fpga::engine::EngineVerdict;
use teda_fpga::persist::FileStore;
use teda_fpga::stream::Sample;
use teda_fpga::util::prng::SplitMix64;

const STREAMS: u64 = 4;
const PER_STREAM: u64 = 90;
const CHECKPOINT_EVERY: u64 = 20;
/// Kill after submitting this seq (NOT checkpoint-aligned on purpose:
/// the replay window re-derives seqs 40..=KILL_AT from the watermark).
const KILL_AT: u64 = 53;
/// Last published watermark before the kill: seq 39 (checkpoints land
/// at (seq+1) % 20 == 0 → 19, 39).
const RESUME_FROM: u64 = 40;

fn artifacts_present() -> bool {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    std::path::Path::new(dir).join("manifest.json").exists()
}

fn cfg(engine: EngineKind) -> ServiceConfig {
    ServiceConfig {
        engine,
        workers: 3,
        n_features: 2,
        queue_capacity: 256,
        checkpoint_every: CHECKPOINT_EVERY,
        restore_on_resume: true,
        artifact_dir: concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")
            .into(),
        // RTL member gives the ensemble open quorums at the kill point;
        // its tighter threshold (m=1.5 vs 3) makes it disagree often, so
        // the adaptive combiner's per-stream weights genuinely evolve —
        // both the quorums and the learned weights must survive failover.
        ensemble: EnsembleConfig::from_member_list(
            "teda:m=3+rtl:m=1.5",
            CombinerKind::Adaptive,
        )
        .unwrap(),
        ..Default::default()
    }
}

/// Deterministic per-(stream, seq) sample so both runs see identical
/// input without sharing RNG state across services.
fn sample(sid: u64, seq: u64) -> Sample {
    let mut rng = SplitMix64::new(sid.wrapping_mul(0x9E37) ^ seq);
    Sample {
        stream_id: sid,
        seq,
        values: vec![rng.uniform(-2.0, 2.0), rng.uniform(-2.0, 2.0)],
    }
}

fn submit_range(svc: &Service, from: u64, to: u64) {
    for seq in from..to {
        for sid in 0..STREAMS {
            svc.submit(sample(sid, seq)).unwrap();
        }
    }
}

fn index(
    out: Vec<teda_fpga::coordinator::Classified>,
    map: &mut BTreeMap<(u64, u64), EngineVerdict>,
) {
    for c in out {
        let key = (c.verdict.stream_id, c.verdict.seq);
        match map.get(&key) {
            // Replay-window duplicates must be IDENTICAL re-derivations
            // (NaN-safe: bit-compare the observables).
            Some(prev) => {
                assert_eq!(prev.k, c.verdict.k, "{key:?}");
                assert_eq!(prev.outlier, c.verdict.outlier, "{key:?}");
                assert_eq!(
                    prev.zeta.to_bits(),
                    c.verdict.zeta.to_bits(),
                    "replayed verdict diverged at {key:?}"
                );
            }
            None => {
                map.insert(key, c.verdict);
            }
        }
    }
}

fn run_uninterrupted(
    engine: EngineKind,
) -> BTreeMap<(u64, u64), EngineVerdict> {
    let svc = Service::start(cfg(engine)).unwrap();
    submit_range(&svc, 0, PER_STREAM);
    let mut map = BTreeMap::new();
    index(svc.finish().unwrap(), &mut map);
    map
}

fn run_with_failover(
    engine: EngineKind,
) -> BTreeMap<(u64, u64), EngineVerdict> {
    // Incarnation 1: processes seqs 0..=KILL_AT, checkpoints at 19/39,
    // then dies without flushing.
    let svc1 = Service::start(cfg(engine)).unwrap();
    let state = svc1.state_manager();
    submit_range(&svc1, 0, KILL_AT + 1);
    let mut map = BTreeMap::new();
    index(svc1.abort().unwrap(), &mut map);
    // The kill lost the in-flight tail: nothing at/after the kill point
    // can be complete for latency > 0 engines, and every stream's
    // newest checkpoint is the seq-39 watermark.
    for sid in 0..STREAMS {
        let cp = state.latest(sid).unwrap_or_else(|| {
            panic!("stream {sid} has no checkpoint before the kill")
        });
        assert_eq!(cp.seq, RESUME_FROM - 1, "stream {sid} watermark");
    }
    // Incarnation 2: inherits the checkpoint store; the at-least-once
    // upstream re-requests everything after the watermark. The worker
    // restores each stream's snapshot on its first resumed sample.
    let svc2 =
        Service::start_with_state(cfg(engine), state.clone()).unwrap();
    submit_range(&svc2, RESUME_FROM, PER_STREAM);
    index(svc2.finish().unwrap(), &mut map);
    map
}

fn assert_failover_invisible(engine: EngineKind) {
    let full = run_uninterrupted(engine);
    let merged = run_with_failover(engine);
    assert_eq!(
        full.len(),
        (STREAMS * PER_STREAM) as usize,
        "{engine}: uninterrupted run must classify everything"
    );
    assert_eq!(
        merged.len(),
        full.len(),
        "{engine}: failover lost or duplicated verdicts"
    );
    for (key, a) in &full {
        let b = &merged[key];
        assert_eq!(a.k, b.k, "{engine} {key:?}");
        assert_eq!(a.outlier, b.outlier, "{engine} {key:?}");
        assert_eq!(
            a.zeta.to_bits(),
            b.zeta.to_bits(),
            "{engine} {key:?}: zeta {} vs {}",
            a.zeta,
            b.zeta
        );
        assert_eq!(a.threshold.to_bits(), b.threshold.to_bits());
    }
}

#[test]
fn software_failover_is_invisible() {
    assert_failover_invisible(EngineKind::Software);
}

#[test]
fn rtl_failover_is_invisible() {
    assert_failover_invisible(EngineKind::Rtl);
}

#[test]
fn ensemble_failover_is_invisible_including_adaptive_weights() {
    assert_failover_invisible(EngineKind::Ensemble);
}

#[test]
fn xla_failover_is_invisible() {
    if !artifacts_present() {
        eprintln!("artifacts missing — skipping XLA failover e2e");
        return;
    }
    assert_failover_invisible(EngineKind::Xla);
}

#[test]
fn inclusive_replay_from_the_watermark_stays_exactly_once() {
    // An at-least-once upstream may replay from the watermark
    // INCLUSIVELY (seq == cp.seq), not just after it. The worker must
    // still restore, drop the already-folded samples, and end up
    // verdict-for-verdict identical — not silently restart the stream.
    let full = run_uninterrupted(EngineKind::Software);
    let svc1 = Service::start(cfg(EngineKind::Software)).unwrap();
    let state = svc1.state_manager();
    submit_range(&svc1, 0, KILL_AT + 1);
    let mut map = BTreeMap::new();
    index(svc1.abort().unwrap(), &mut map);
    let svc2 =
        Service::start_with_state(cfg(EngineKind::Software), state).unwrap();
    // Replay window starts AT the watermark and overlaps further back.
    submit_range(&svc2, RESUME_FROM - 1, PER_STREAM);
    let m = svc2.metrics();
    index(svc2.finish().unwrap(), &mut map);
    assert_eq!(m.stream_restores.get(), STREAMS);
    // One already-folded sample (the watermark itself) dropped per stream.
    assert_eq!(m.replay_skipped.get(), STREAMS);
    assert_eq!(map.len(), full.len());
    for (key, a) in &full {
        let b = &map[key];
        assert_eq!((a.k, a.outlier), (b.k, b.outlier), "{key:?}");
        assert_eq!(a.zeta.to_bits(), b.zeta.to_bits(), "{key:?}");
    }
}

// ------------------------------------------------ full-process death

fn tmp_ckpt_dir(tag: &str) -> PathBuf {
    teda_fpga::util::unique_temp_dir(&format!("failover-{tag}"))
}

/// Like [`run_with_failover`], but NOTHING survives in memory between
/// the incarnations: incarnation 1 writes checkpoints through to a
/// durable [`FileStore`], dies via `abort()`, and every in-process
/// handle (service, `StateManager`, store) is dropped. Incarnation 2 is
/// built from the directory alone via [`Service::start_from_store`] —
/// exactly what a restarted process with `--recover` does.
fn run_with_process_death(
    engine: EngineKind,
) -> BTreeMap<(u64, u64), EngineVerdict> {
    let dir = tmp_ckpt_dir(&engine.to_string());
    let mut map = BTreeMap::new();
    {
        let mut c1 = cfg(engine);
        c1.checkpoint_dir = Some(dir.clone());
        let svc1 = Service::start(c1).unwrap();
        submit_range(&svc1, 0, KILL_AT + 1);
        index(svc1.abort().unwrap(), &mut map);
        // Scope end: the dead process's entire memory is gone.
    }
    let mut c2 = cfg(engine);
    c2.checkpoint_dir = Some(dir.clone());
    let store = FileStore::open(&dir, c2.checkpoint_keep).unwrap();
    let svc2 = Service::start_from_store(c2, Arc::new(store)).unwrap();
    let state = svc2.state_manager();
    // Cold-start recovery found every stream's on-disk watermark.
    for sid in 0..STREAMS {
        let cp = state.latest(sid).unwrap_or_else(|| {
            panic!("stream {sid} not recovered from disk")
        });
        assert_eq!(cp.seq, RESUME_FROM - 1, "stream {sid} watermark");
    }
    submit_range(&svc2, RESUME_FROM, PER_STREAM);
    index(svc2.finish().unwrap(), &mut map);
    assert_eq!(state.persist_errors(), 0);
    std::fs::remove_dir_all(&dir).unwrap();
    map
}

fn assert_process_death_invisible(engine: EngineKind) {
    let full = run_uninterrupted(engine);
    let merged = run_with_process_death(engine);
    assert_eq!(
        merged.len(),
        full.len(),
        "{engine}: process death lost or duplicated verdicts"
    );
    for (key, a) in &full {
        let b = &merged[key];
        assert_eq!(a.k, b.k, "{engine} {key:?}");
        assert_eq!(a.outlier, b.outlier, "{engine} {key:?}");
        assert_eq!(
            a.zeta.to_bits(),
            b.zeta.to_bits(),
            "{engine} {key:?}: zeta {} vs {}",
            a.zeta,
            b.zeta
        );
        assert_eq!(a.threshold.to_bits(), b.threshold.to_bits());
    }
}

#[test]
fn software_survives_full_process_death() {
    assert_process_death_invisible(EngineKind::Software);
}

#[test]
fn rtl_survives_full_process_death() {
    assert_process_death_invisible(EngineKind::Rtl);
}

#[test]
fn ensemble_survives_full_process_death() {
    assert_process_death_invisible(EngineKind::Ensemble);
}

#[test]
fn xla_survives_full_process_death() {
    if !artifacts_present() {
        eprintln!("artifacts missing — skipping XLA process-death e2e");
        return;
    }
    assert_process_death_invisible(EngineKind::Xla);
}

#[test]
fn without_recover_the_restarted_process_diverges() {
    // Control experiment: the checkpoints ARE on disk, but a restarted
    // process that does not cold-start from the store silently restarts
    // every stream at k = 1 — the gap `--recover` exists to close.
    let dir = tmp_ckpt_dir("control");
    {
        let mut c1 = cfg(EngineKind::Software);
        c1.checkpoint_dir = Some(dir.clone());
        let svc1 = Service::start(c1).unwrap();
        submit_range(&svc1, 0, KILL_AT + 1);
        svc1.abort().unwrap();
    }
    let mut c2 = cfg(EngineKind::Software);
    c2.checkpoint_dir = Some(dir.clone());
    c2.restore_on_resume = false;
    let svc2 = Service::start(c2).unwrap(); // plain start: no recover
    submit_range(&svc2, RESUME_FROM, PER_STREAM);
    let out = svc2.finish().unwrap();
    let resumed = out
        .iter()
        .find(|c| c.verdict.seq == RESUME_FROM)
        .expect("resumed verdicts exist");
    assert_eq!(
        resumed.verdict.k, 1,
        "un-recovered process restarted the stream"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn without_restore_the_resumed_run_diverges() {
    // Control experiment: the same failover WITHOUT restore-on-resume
    // silently restarts streams at k=1 — today's bug, now observable.
    let mut c = cfg(EngineKind::Software);
    c.restore_on_resume = false;
    let svc1 = Service::start(c.clone()).unwrap();
    let state = svc1.state_manager();
    submit_range(&svc1, 0, KILL_AT + 1);
    svc1.abort().unwrap();
    let svc2 = Service::start_with_state(c, state).unwrap();
    submit_range(&svc2, RESUME_FROM, PER_STREAM);
    let out = svc2.finish().unwrap();
    // Every resumed verdict has a reset k (counts from 1 again) —
    // provably NOT a continuation.
    let resumed = out
        .iter()
        .find(|c| c.verdict.seq == RESUME_FROM)
        .expect("resumed verdicts exist");
    assert_eq!(resumed.verdict.k, 1, "fresh engine restarted the stream");
}

//! Integration: the AOT-compiled JAX/Pallas artifact, loaded through the
//! PJRT runtime, must agree with the Rust TEDA oracle (f32).
//!
//! Requires `make artifacts` to have run; tests are skipped (pass
//! trivially with a notice) when artifacts/ is absent so `cargo test`
//! stays green on a fresh checkout.

use teda_fpga::runtime::XlaRuntime;
use teda_fpga::teda::TedaState;
use teda_fpga::util::prng::SplitMix64;

fn artifact_dir() -> Option<String> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if std::path::Path::new(dir).join("manifest.json").exists() {
        Some(dir.to_string())
    } else {
        eprintln!("artifacts/ missing; run `make artifacts` — skipping");
        None
    }
}

/// Run one chunk through the artifact and through the f32 oracle; compare.
fn check_variant(rt: &XlaRuntime, name: &str, seed: u64) {
    let exe = rt.load(name).expect("load variant");
    let spec = exe.spec().clone();
    let (s, n, t) = (spec.s, spec.n, spec.t);

    // Random warm state + chunk.
    let mut rng = SplitMix64::new(seed);
    let mu: Vec<f32> =
        (0..s * n).map(|_| rng.uniform(-0.5, 0.5) as f32).collect();
    let var: Vec<f32> = (0..s).map(|_| rng.uniform(0.2, 2.0) as f32).collect();
    let k: Vec<f32> = (0..s).map(|_| (rng.below(200) + 2) as f32).collect();
    let x: Vec<f32> =
        (0..s * t * n).map(|_| rng.uniform(-2.0, 2.0) as f32).collect();

    let outs = exe
        .run_f32(&[&mu, &var, &k, &x])
        .expect("execute");
    let (ecc, zeta, outlier) = (&outs[0], &outs[1], &outs[2]);
    let (mu2, var2, k2) = (&outs[3], &outs[4], &outs[5]);

    // Oracle: per-stream recursive TEDA in f32.
    for si in 0..s {
        let mut st = TedaState::<f32> {
            mean: mu[si * n..(si + 1) * n].to_vec(),
            var: var[si],
            k: k[si] as u64,
        };
        for ti in 0..t {
            let sample = &x[(si * t + ti) * n..(si * t + ti + 1) * n];
            let step = st.step(sample, spec.m as f32);
            let idx = si * t + ti;
            let tol = 1e-3_f32; // fp reassociation XLA-vs-Rust
            assert!(
                (ecc[idx] - step.eccentricity).abs()
                    <= tol * step.eccentricity.abs().max(1.0),
                "{name} ecc s={si} t={ti}: {} vs {}",
                ecc[idx],
                step.eccentricity
            );
            assert!(
                (zeta[idx] - step.zeta).abs() <= tol * step.zeta.abs().max(1.0),
                "{name} zeta s={si} t={ti}"
            );
            // Outlier bits may legitimately differ within fp tolerance of
            // the threshold; only compare when zeta is clearly away from it.
            let margin = (step.zeta - step.threshold).abs();
            if margin > 1e-4 * step.threshold.max(1e-3) {
                assert_eq!(
                    outlier[idx] > 0.5,
                    step.outlier,
                    "{name} outlier s={si} t={ti} zeta={} thr={}",
                    step.zeta,
                    step.threshold
                );
            }
        }
        // Final state must carry over.
        for fi in 0..n {
            let got = mu2[si * n + fi];
            let want = st.mean[fi];
            assert!(
                (got - want).abs() <= 1e-3 * want.abs().max(1.0),
                "{name} mu' s={si} f={fi}: {got} vs {want}"
            );
        }
        assert!(
            (var2[si] - st.var).abs() <= 1e-3 * st.var.abs().max(1.0),
            "{name} var' s={si}: {} vs {}",
            var2[si],
            st.var
        );
        assert_eq!(k2[si] as u64, st.k, "{name} k' s={si}");
    }
}

#[test]
fn artifact_matches_rust_oracle_all_variants() {
    let Some(dir) = artifact_dir() else { return };
    let rt = XlaRuntime::new(&dir).expect("runtime");
    assert_eq!(rt.platform(), "cpu");
    let names: Vec<String> = rt
        .manifest()
        .variants
        .iter()
        .filter(|v| v.kernel == "pallas")
        .map(|v| v.name.clone())
        .collect();
    assert!(!names.is_empty());
    for (i, name) in names.iter().enumerate() {
        check_variant(&rt, name, 1000 + i as u64);
    }
}

#[test]
fn artifact_fresh_state_first_sample_not_outlier() {
    let Some(dir) = artifact_dir() else { return };
    let rt = XlaRuntime::new(&dir).expect("runtime");
    let spec = rt.manifest().select(2, 1).expect("n=2 variant").clone();
    let exe = rt.load(&spec.name).unwrap();
    let (s, n, t) = (spec.s, spec.n, spec.t);
    let mu = vec![0f32; s * n];
    let var = vec![0f32; s];
    let k = vec![0f32; s];
    let mut rng = SplitMix64::new(7);
    let x: Vec<f32> =
        (0..s * t * n).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
    let outs = exe.run_f32(&[&mu, &var, &k, &x]).unwrap();
    let outlier = &outs[2];
    for si in 0..s {
        assert_eq!(outlier[si * t], 0.0, "k=1 must never flag (stream {si})");
    }
    // k' must equal t for every stream.
    for si in 0..s {
        assert_eq!(outs[5][si], t as f32);
    }
}

#[test]
fn executable_rejects_wrong_arity_and_shape() {
    let Some(dir) = artifact_dir() else { return };
    let rt = XlaRuntime::new(&dir).expect("runtime");
    let spec = rt.manifest().variants[0].clone();
    let exe = rt.load(&spec.name).unwrap();
    // Wrong number of inputs.
    assert!(exe.run_f32(&[&[0.0]]).is_err());
    // Right arity, wrong length.
    let bad = vec![0f32; 3];
    let ok_var = vec![0f32; spec.s];
    let ok_k = vec![0f32; spec.s];
    let ok_x = vec![0f32; spec.s * spec.t * spec.n];
    assert!(exe.run_f32(&[&bad, &ok_var, &ok_k, &ok_x]).is_err());
}

#[test]
fn chunked_equals_oneshot_through_artifact() {
    // Feeding 2×T/2 chunks with carried state == the oracle's full run.
    let Some(dir) = artifact_dir() else { return };
    let rt = XlaRuntime::new(&dir).expect("runtime");
    let spec = rt.manifest().select(2, 1).expect("n=2").clone();
    let exe = rt.load(&spec.name).unwrap();
    let (s, n, t) = (spec.s, spec.n, spec.t);

    let mut rng = SplitMix64::new(21);
    let x: Vec<f32> =
        (0..s * t * n).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();

    // Chunk 1.
    let mu0 = vec![0f32; s * n];
    let var0 = vec![0f32; s];
    let k0 = vec![0f32; s];
    let o1 = exe.run_f32(&[&mu0, &var0, &k0, &x]).unwrap();
    // Chunk 2 continues from chunk 1's state.
    let x2: Vec<f32> =
        (0..s * t * n).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
    let o2 = exe.run_f32(&[&o1[3], &o1[4], &o1[5], &x2]).unwrap();

    // Oracle over the concatenated stream.
    for si in 0..s.min(4) {
        let mut st = TedaState::<f32>::new(n);
        for ti in 0..t {
            st.step(&x[(si * t + ti) * n..(si * t + ti + 1) * n], spec.m as f32);
        }
        for ti in 0..t {
            let step = st
                .step(&x2[(si * t + ti) * n..(si * t + ti + 1) * n], spec.m as f32);
            let idx = si * t + ti;
            assert!(
                (o2[1][idx] - step.zeta).abs() <= 2e-3 * step.zeta.abs().max(1.0),
                "s={si} t={ti}: {} vs {}",
                o2[1][idx],
                step.zeta
            );
        }
        assert_eq!(o2[5][si] as u64, st.k);
    }
}

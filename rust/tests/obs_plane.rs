//! Observability-plane end-to-end battery (ISSUE 7): a live service
//! driving real traffic, scraped over HTTP while it runs.
//!
//! Invariants under test:
//! - **Registry → exposition**: every `ServiceMetrics` registry row
//!   appears on the wire with `# HELP` / `# TYPE` lines, and live
//!   counters scrape monotonically across consecutive scrapes.
//! - **Stage tracing**: after batched traffic, the queue-wait /
//!   engine / emit histograms are populated and decompose end-to-end
//!   latency (each stage p99 is bounded by a sane ceiling).
//! - **Flight recorder**: `/trace` serves a merged timeline containing
//!   the events the run actually performed.
//! - **Windows**: `MetricsWindow` reports per-interval deltas that sum
//!   to the lifetime totals, never double-counting across ticks.

use std::io::{Read, Write};
use std::net::TcpStream;

use teda_fpga::config::{EngineKind, ServiceConfig, ShardingConfig};
use teda_fpga::coordinator::Service;
use teda_fpga::obs::MetricsServer;
use teda_fpga::stream::Sample;
use teda_fpga::util::prng::SplitMix64;

const STREAMS: u64 = 8;
const PER_STREAM: u64 = 150;

fn cfg() -> ServiceConfig {
    ServiceConfig {
        engine: EngineKind::Software,
        workers: 2,
        n_features: 2,
        queue_capacity: 1024,
        sharding: ShardingConfig { virtual_shards: 32, ..Default::default() },
        ..Default::default()
    }
}

fn sample(sid: u64, seq: u64) -> Sample {
    let mut rng = SplitMix64::new(sid.wrapping_mul(0x51D7) ^ seq);
    Sample {
        stream_id: sid,
        seq,
        values: vec![rng.uniform(-2.0, 2.0), rng.uniform(-2.0, 2.0)],
    }
}

/// Drive `PER_STREAM` batched rounds through the service.
fn drive(svc: &Service) {
    let handle = svc.handle();
    for seq in 0..PER_STREAM {
        let burst: Vec<Sample> =
            (0..STREAMS).map(|sid| sample(sid, seq)).collect();
        handle.submit_batch(burst).unwrap();
    }
}

fn get(addr: std::net::SocketAddr, path: &str) -> (u16, String) {
    let mut conn = TcpStream::connect(addr).unwrap();
    conn.write_all(
        format!("GET {path} HTTP/1.1\r\nHost: t\r\n\r\n").as_bytes(),
    )
    .unwrap();
    let mut raw = String::new();
    conn.read_to_string(&mut raw).unwrap();
    let (head, body) = raw.split_once("\r\n\r\n").unwrap();
    let status: u16 =
        head.split_whitespace().nth(1).unwrap().parse().unwrap();
    (status, body.to_string())
}

/// Value of a plain (label-free) sample line in an exposition body.
fn sample_value(body: &str, name: &str) -> Option<f64> {
    body.lines()
        .find(|l| l.starts_with(name) && l[name.len()..].starts_with(' '))
        .and_then(|l| l.rsplit_once(' '))
        .and_then(|(_, v)| v.parse().ok())
}

#[test]
fn live_scrape_is_complete_and_monotonic() {
    let svc = Service::start(cfg()).unwrap();
    drive(&svc);
    let mut srv =
        MetricsServer::start("127.0.0.1:0", svc.metrics(), None).unwrap();
    let addr = srv.local_addr();

    let (status, first) = get(addr, "/metrics");
    assert_eq!(status, 200);
    // Every registry row is on the wire with its metadata.
    for m in svc.metrics().registry() {
        let family = format!("teda_{}", m.name);
        assert!(
            first.contains(&format!("# HELP {family} ")),
            "missing HELP for {family}"
        );
        assert!(
            first.contains(&format!("# TYPE {family} ")),
            "missing TYPE for {family}"
        );
    }
    let in_1 = sample_value(&first, "teda_samples_in").unwrap();
    assert!(in_1 > 0.0, "samples_in must be nonzero after traffic");

    // More traffic, then a second scrape: counters move monotonically.
    drive(&svc);
    let (_, second) = get(addr, "/metrics");
    let in_2 = sample_value(&second, "teda_samples_in").unwrap();
    assert!(in_2 >= in_1 + 1.0, "counter went {in_1} → {in_2}");
    for name in ["teda_verdicts_out", "teda_outliers"] {
        let a = sample_value(&first, name).unwrap();
        let b = sample_value(&second, name).unwrap();
        assert!(b >= a, "{name} regressed {a} → {b}");
    }

    srv.stop();
    svc.finish().unwrap();
}

#[test]
fn stage_histograms_decompose_latency_end_to_end() {
    let svc = Service::start(cfg()).unwrap();
    drive(&svc);
    let metrics = svc.metrics();
    let out = svc.finish().unwrap();
    assert_eq!(out.len(), (STREAMS * PER_STREAM) as usize);

    // Every stage saw traffic...
    assert!(metrics.latency.count() > 0);
    assert!(metrics.queue_wait.count() > 0, "queue_wait never recorded");
    assert!(metrics.engine_time.count() > 0, "engine_time never recorded");
    assert!(metrics.emit_time.count() > 0, "emit_time never recorded");
    // ...and the per-burst stages record once per dequeue, not once per
    // sample (the hot-path discipline the bench gate protects).
    assert!(metrics.engine_time.count() <= metrics.queue_wait.count());
    // Stage p99s are real durations, not garbage (< 60 s each).
    for h in [&metrics.queue_wait, &metrics.engine_time, &metrics.emit_time]
    {
        let p99 = h.quantile(0.99);
        assert!(p99 > 0, "stage histogram has a zero p99");
        assert!(p99 < 60_000_000_000, "stage p99 {p99}ns is implausible");
    }
}

#[test]
fn trace_endpoint_serves_the_runs_events() {
    let svc = Service::start(cfg()).unwrap();
    let mut srv =
        MetricsServer::start("127.0.0.1:0", svc.metrics(), None).unwrap();
    drive(&svc);
    svc.finish().unwrap();

    let (status, body) = get(srv.local_addr(), "/trace");
    assert_eq!(status, 200);
    assert!(body.contains("flight recorder: last"), "missing header");
    // Batched submits journal Submit on the producer and Dequeue on the
    // worker; both must appear in the merged tail of this process.
    assert!(body.contains("Submit"), "no Submit events in:\n{body}");
    assert!(body.contains("Dequeue"), "no Dequeue events in:\n{body}");
    srv.stop();
}

#[test]
fn windows_report_interval_deltas_that_sum_to_lifetime() {
    let svc = Service::start(cfg()).unwrap();
    let mut window = svc.metrics_window();

    drive(&svc);
    let r1 = window.tick(&svc.metrics());
    let d1 = r1.delta("samples_in");
    assert!(d1 > 0, "first window saw no traffic");
    assert!(r1.rate("samples_in") > 0.0);

    drive(&svc);
    let r2 = window.tick(&svc.metrics());
    let d2 = r2.delta("samples_in");
    assert!(d2 > 0, "second window saw no traffic");

    // Deltas partition the lifetime counter: no double counting.
    assert_eq!(d1 + d2, svc.metrics().samples_in.get());

    // A quiet window reports zero rate, not a stale carry-over.
    let r3 = window.tick(&svc.metrics());
    assert_eq!(r3.delta("samples_in"), 0);
    svc.finish().unwrap();
}

#[test]
fn queue_depth_gauges_are_exposed_per_worker() {
    let svc = Service::start(cfg()).unwrap();
    let depths = svc.queue_depths();
    assert_eq!(depths.len(), 2, "one gauge per worker");
    drive(&svc);
    svc.finish().unwrap();
}

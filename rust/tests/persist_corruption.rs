//! Corruption battery for the durable checkpoint store.
//!
//! The recovery contract under attack: PRNG-driven bit flips,
//! truncations, zero-length files, and garbage records must ALWAYS
//! yield a clean decode error — never a panic, never a silently wrong
//! state — and `recover()` must fall back to the newest still-valid
//! earlier checkpoint when the tail of a stream's history is damaged.

use std::fs;
use std::path::PathBuf;
use std::sync::Arc;

use teda_fpga::config::{CombinerKind, EnsembleConfig};
use teda_fpga::coordinator::{StateCheckpoint, StateManager};
use teda_fpga::engine::{Engine, RtlEngine, SoftwareEngine};
use teda_fpga::ensemble::EnsembleEngine;
use teda_fpga::persist::{codec, CheckpointStore, FileStore};
use teda_fpga::stream::Sample;
use teda_fpga::util::prng::SplitMix64;

fn tmp_root(tag: &str) -> PathBuf {
    teda_fpga::util::unique_temp_dir(&format!("corruption-{tag}"))
}

/// A checkpoint with real (non-trivial) state from `engine`, fed
/// `upto + 1` samples of stream `sid`.
fn checkpoint_from(
    engine: &mut dyn Engine,
    sid: u64,
    upto: u64,
) -> StateCheckpoint {
    let mut rng = SplitMix64::new(sid ^ 0xC0FFEE);
    for seq in 0..=upto {
        engine
            .ingest(&Sample {
                stream_id: sid,
                seq,
                values: vec![rng.uniform(-2.0, 2.0), rng.uniform(-2.0, 2.0)],
            })
            .unwrap();
    }
    StateCheckpoint {
        stream_id: sid,
        seq: upto,
        snapshot: engine.snapshot(sid).unwrap(),
    }
}

/// Encoded records covering every snapshot family (XLA synthetically —
/// the codec must not depend on AOT artifacts being present).
fn sample_records() -> Vec<(&'static str, Vec<u8>)> {
    let cfg = EnsembleConfig::from_member_list(
        "teda:m=3+rtl:m=2+msigma:m=3+zscore:m=3,w=8",
        CombinerKind::Adaptive,
    )
    .unwrap();
    vec![
        (
            "software",
            codec::encode(&checkpoint_from(
                &mut SoftwareEngine::new(2, 3.0),
                1,
                40,
            )),
        ),
        (
            "rtl",
            codec::encode(&checkpoint_from(
                &mut RtlEngine::new(2, 3.0),
                2,
                40,
            )),
        ),
        (
            "ensemble",
            codec::encode(&checkpoint_from(
                &mut EnsembleEngine::new(&cfg, 2).unwrap(),
                3,
                40,
            )),
        ),
        (
            "xla",
            codec::encode(&StateCheckpoint {
                stream_id: 4,
                seq: 40,
                snapshot: teda_fpga::engine::Snapshot::Xla(
                    teda_fpga::engine::XlaSnapshot {
                        mu: vec![0.5, -0.5],
                        var: 0.25,
                        k: 32.0,
                        m: 3.0,
                        chunks: vec![
                            (32, vec![0.1; 16]),
                            (40, vec![0.2; 16]),
                        ],
                        buf: vec![1.5, -1.5],
                        seq_base: 48,
                    },
                ),
            }),
        ),
    ]
}

#[test]
fn single_bit_flips_never_decode() {
    // Any single-bit flip lands in the header (magic/version/flags/
    // length/CRC — all strictly validated) or in the payload (CRC
    // mismatch). Either way: a clean error. 256 PRNG-chosen positions
    // per snapshot family.
    let mut rng = SplitMix64::new(0xB17F11B5);
    for (label, good) in sample_records() {
        assert!(codec::decode(&good).is_ok(), "{label}: pristine record");
        for trial in 0..256 {
            let mut bad = good.clone();
            let bit = rng.next_u64() as usize % (bad.len() * 8);
            bad[bit / 8] ^= 1 << (bit % 8);
            let res = codec::decode(&bad);
            assert!(
                res.is_err(),
                "{label} trial {trial}: flipped bit {bit} still decoded"
            );
        }
    }
}

#[test]
fn multi_bit_corruption_never_decodes_or_lies() {
    // Heavier damage: 2..=64 flipped bits per trial. Decoding may in
    // principle survive only if the record is bit-identical to the
    // original — anything else must be an error (a decode that
    // succeeded with DIFFERENT bytes yet equal content is fine; one
    // with different content is the catastrophic "silently wrong
    // state" and fails the assert).
    let mut rng = SplitMix64::new(0x5EED);
    for (label, good) in sample_records() {
        let original = codec::decode(&good).unwrap();
        for trial in 0..128 {
            let mut bad = good.clone();
            let flips = 2 + (rng.next_u64() % 63) as usize;
            for _ in 0..flips {
                let bit = rng.next_u64() as usize % (bad.len() * 8);
                bad[bit / 8] ^= 1 << (bit % 8);
            }
            if bad == good {
                continue; // flips cancelled out
            }
            match codec::decode(&bad) {
                Err(_) => {}
                Ok(cp) => assert_eq!(
                    cp, original,
                    "{label} trial {trial}: corrupt record decoded to \
                     DIFFERENT state"
                ),
            }
        }
    }
}

#[test]
fn every_truncation_is_a_clean_error() {
    for (label, good) in sample_records() {
        for cut in 0..good.len() {
            assert!(
                codec::decode(&good[..cut]).is_err(),
                "{label}: truncation to {cut}/{} bytes decoded",
                cut,
            );
        }
    }
}

#[test]
fn zero_length_and_garbage_records_are_clean_errors() {
    assert!(codec::decode(&[]).is_err());
    let mut rng = SplitMix64::new(7);
    for len in [1usize, 7, 19, 20, 21, 64, 1024] {
        let garbage: Vec<u8> =
            (0..len).map(|_| rng.next_u64() as u8).collect();
        assert!(
            codec::decode(&garbage).is_err(),
            "{len} bytes of garbage decoded"
        );
    }
}

/// Write a valid two-checkpoint history for stream `sid`, then damage
/// the newest on-disk record with `damage`.
fn store_with_damaged_tail(
    tag: &str,
    damage: impl Fn(&PathBuf),
) -> (PathBuf, FileStore) {
    let root = tmp_root(tag);
    let store = FileStore::open(&root, 4).unwrap();
    let mut eng = SoftwareEngine::new(2, 3.0);
    let older = checkpoint_from(&mut eng, 5, 19); // seqs 0..=19
    store.put(&older).unwrap();
    // Continue the SAME engine to seq 39 for the newer checkpoint.
    let mut rng = SplitMix64::new(99);
    for seq in 20..=39u64 {
        eng.ingest(&Sample {
            stream_id: 5,
            seq,
            values: vec![rng.uniform(-2.0, 2.0), rng.uniform(-2.0, 2.0)],
        })
        .unwrap();
    }
    store
        .put(&StateCheckpoint {
            stream_id: 5,
            seq: 39,
            snapshot: eng.snapshot(5).unwrap(),
        })
        .unwrap();
    let newest = root.join("5").join(format!("{:020}.ckpt", 39));
    assert!(newest.exists());
    damage(&newest);
    (root, store)
}

#[test]
fn recovery_falls_back_past_a_bit_flipped_tail() {
    let (root, store) = store_with_damaged_tail("bitflip", |path| {
        let mut bytes = fs::read(path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        fs::write(path, bytes).unwrap();
    });
    assert_eq!(
        store.latest(5).unwrap().unwrap().seq,
        19,
        "latest() must skip the corrupt tail"
    );
    let mgr = StateManager::with_store(Arc::new(store));
    assert_eq!(mgr.recover().unwrap(), 1);
    assert_eq!(mgr.latest(5).unwrap().seq, 19);
    fs::remove_dir_all(&root).unwrap();
}

#[test]
fn recovery_falls_back_past_a_truncated_tail() {
    let (root, store) = store_with_damaged_tail("truncate", |path| {
        let bytes = fs::read(path).unwrap();
        fs::write(path, &bytes[..bytes.len() / 3]).unwrap();
    });
    assert_eq!(store.latest(5).unwrap().unwrap().seq, 19);
    fs::remove_dir_all(&root).unwrap();
}

#[test]
fn recovery_falls_back_past_a_zero_length_tail() {
    let (root, store) = store_with_damaged_tail("zerolen", |path| {
        fs::write(path, b"").unwrap();
    });
    assert_eq!(store.latest(5).unwrap().unwrap().seq, 19);
    let mgr = StateManager::with_store(Arc::new(store));
    assert_eq!(mgr.recover().unwrap(), 1);
    assert_eq!(mgr.latest(5).unwrap().seq, 19);
    fs::remove_dir_all(&root).unwrap();
}

#[test]
fn all_checkpoints_corrupt_means_no_recovery_not_a_wrong_one() {
    let root = tmp_root("all-bad");
    let store = FileStore::open(&root, 4).unwrap();
    store
        .put(&checkpoint_from(&mut SoftwareEngine::new(2, 3.0), 9, 19))
        .unwrap();
    let path = root.join("9").join(format!("{:020}.ckpt", 19));
    fs::write(&path, b"not a checkpoint at all").unwrap();
    assert!(store.latest(9).unwrap().is_none());
    let mgr = StateManager::with_store(Arc::new(store));
    assert_eq!(mgr.recover().unwrap(), 0, "nothing valid → nothing loaded");
    assert!(mgr.latest(9).is_none());
    fs::remove_dir_all(&root).unwrap();
}

#[test]
fn record_under_a_wrong_filename_is_treated_as_corrupt() {
    // A checkpoint copied to another stream's directory (or renamed to
    // a different seq) must not be loaded: the payload's identity wins.
    let root = tmp_root("misfiled");
    let store = FileStore::open(&root, 4).unwrap();
    store
        .put(&checkpoint_from(&mut SoftwareEngine::new(2, 3.0), 1, 19))
        .unwrap();
    // Copy stream 1's record into stream 2's directory.
    let src = root.join("1").join(format!("{:020}.ckpt", 19));
    fs::create_dir_all(root.join("2")).unwrap();
    fs::copy(&src, root.join("2").join(format!("{:020}.ckpt", 19)))
        .unwrap();
    // And to a wrong seq within its own stream.
    fs::copy(&src, root.join("1").join(format!("{:020}.ckpt", 99)))
        .unwrap();
    assert!(store.latest(2).unwrap().is_none());
    assert_eq!(store.latest(1).unwrap().unwrap().seq, 19);
    fs::remove_dir_all(&root).unwrap();
}

#[test]
fn decoded_checkpoint_restores_into_a_live_engine() {
    // End of the chain: a record that survives decode actually drives
    // an engine — decode is not just structural equality.
    let mut live = SoftwareEngine::new(2, 3.0);
    let cp = checkpoint_from(&mut live, 7, 30);
    let decoded = codec::decode(&codec::encode(&cp)).unwrap();
    let mut restored = SoftwareEngine::new(2, 3.0);
    restored.restore(7, decoded.snapshot).unwrap();
    let probe = Sample { stream_id: 7, seq: 31, values: vec![0.9, -0.9] };
    assert_eq!(
        live.ingest(&probe).unwrap(),
        restored.ingest(&probe).unwrap()
    );
}

//! Ingest hot-path stress battery for the lock-free, batch-first
//! submit core: concurrent single-sample and batched submitters racing
//! live worker scaling and forced shard migrations.
//!
//! Invariants under test:
//! - **No lost verdicts**: when no pathologically late stray was
//!   dropped (`stale_drops == 0`, the documented contract), every
//!   submitted sample produces exactly one verdict.
//! - **No contradictory duplicates**: re-emitted in-flight verdicts
//!   after a migration are only legal as identical re-derivations.
//! - **Monotone per-stream seq**: each stream's verdict set is free of
//!   contradictions and (strict mode) covers 0..N exactly.
//! - **Batch/single equivalence**: the batched submit path must be
//!   bit-identical to per-sample submission.
//! - **Run-coalescing under churn**: long same-stream runs split across
//!   forced migrations still match the scalar reference bit-for-bit.
//! - **Losslessness at queue_capacity = 1**: the smallest legal ring
//!   still delivers everything (pure backpressure, no drops).
//!
//! Streams are partitioned across submitter threads (the service's
//! ordering contract: one submitting thread per stream).

use std::collections::BTreeMap;
use std::time::Duration;

use teda_fpga::config::{EngineKind, ServiceConfig, ShardingConfig};
use teda_fpga::coordinator::Service;
use teda_fpga::engine::EngineVerdict;
use teda_fpga::stream::Sample;
use teda_fpga::util::prng::SplitMix64;

const STREAMS: u64 = 8;
const PER_STREAM: u64 = 200;
const THREADS: u64 = 4;

fn cfg(workers: usize, queue_capacity: usize) -> ServiceConfig {
    ServiceConfig {
        engine: EngineKind::Software,
        workers,
        n_features: 2,
        queue_capacity,
        sharding: ShardingConfig {
            virtual_shards: 32,
            ..Default::default()
        },
        ..Default::default()
    }
}

/// Deterministic per-(stream, seq) sample, shared by every run shape.
fn sample(sid: u64, seq: u64) -> Sample {
    let mut rng = SplitMix64::new(sid.wrapping_mul(0x51D7) ^ seq);
    Sample {
        stream_id: sid,
        seq,
        values: vec![rng.uniform(-2.0, 2.0), rng.uniform(-2.0, 2.0)],
    }
}

type VerdictMap = BTreeMap<(u64, u64), EngineVerdict>;

/// Everything a verdict asserts, bit-exact (floats compared by bits).
fn key_fields(v: &EngineVerdict) -> (u64, bool, u64, u64) {
    (v.k, v.outlier, v.zeta.to_bits(), v.threshold.to_bits())
}

/// Index verdicts by (stream, seq), failing on contradictory
/// duplicates (identical re-derivations after a migration are legal).
fn index(out: Vec<teda_fpga::coordinator::Classified>) -> VerdictMap {
    let mut map = VerdictMap::new();
    for c in out {
        let key = (c.verdict.stream_id, c.verdict.seq);
        if let Some(prev) = map.get(&key) {
            assert_eq!(
                key_fields(prev),
                key_fields(&c.verdict),
                "contradictory dup at {key:?}"
            );
        } else {
            map.insert(key, c.verdict);
        }
    }
    map
}

#[test]
fn concurrent_submitters_race_scaling_and_migrations() {
    let svc = Service::start(cfg(3, 64)).unwrap();
    std::thread::scope(|scope| {
        // Streams partitioned per thread: thread t owns sids with
        // sid % THREADS == t. Even threads use the single-sample path,
        // odd threads the batched path — both race the churn below.
        for t in 0..THREADS {
            let handle = svc.handle();
            scope.spawn(move || {
                let sids: Vec<u64> = (0..STREAMS).filter(|sid| sid % THREADS == t).collect();
                if t % 2 == 0 {
                    for seq in 0..PER_STREAM {
                        for &sid in &sids {
                            handle.submit(sample(sid, seq)).unwrap();
                        }
                    }
                } else {
                    for chunk in (0..PER_STREAM).collect::<Vec<_>>().chunks(16) {
                        let burst: Vec<Sample> = chunk
                            .iter()
                            .flat_map(|&seq| sids.iter().map(move |&sid| sample(sid, seq)))
                            .collect();
                        handle.submit_batch(burst).unwrap();
                    }
                }
            });
        }
        // Churn while the submitters run: grow, force a migration off
        // worker 0, shrink below the starting size, grow again.
        let pause = Duration::from_millis(3);
        std::thread::sleep(pause);
        svc.scale_to(5).unwrap();
        std::thread::sleep(pause);
        let moves: Vec<(u32, usize)> = svc
            .table()
            .shards_on(0)
            .into_iter()
            .map(|s| (s, 1))
            .collect();
        svc.migrate_shards(&moves).unwrap();
        std::thread::sleep(pause);
        svc.scale_to(2).unwrap();
        std::thread::sleep(pause);
        svc.scale_to(4).unwrap();
    });
    let metrics = svc.metrics();
    let stale = metrics.stale_drops.get();
    let submitted = metrics.samples_in.get();
    assert_eq!(submitted, STREAMS * PER_STREAM, "samples_in miscounted");
    let map = index(svc.finish().unwrap());
    if stale == 0 {
        // Strict mode: complete coverage, nothing lost anywhere.
        assert_eq!(map.len() as u64, STREAMS * PER_STREAM);
        for sid in 0..STREAMS {
            for seq in 0..PER_STREAM {
                assert!(
                    map.contains_key(&(sid, seq)),
                    "verdict lost at ({sid}, {seq})"
                );
            }
        }
    } else {
        // Lenient mode (counted late-stray drops): nothing beyond the
        // counted drops may be missing.
        assert!(
            map.len() as u64 >= STREAMS * PER_STREAM - stale,
            "lost more verdicts ({}) than counted stale drops ({stale})",
            STREAMS * PER_STREAM - map.len() as u64
        );
    }
}

#[test]
fn batched_submits_are_bit_identical_to_single() {
    let run = |batched: bool| -> VerdictMap {
        let svc = Service::start(cfg(3, 64)).unwrap();
        if batched {
            // Mixed burst sizes, including size 1 and cross-stream
            // bursts, all through the shared batched core.
            let mut burst = Vec::new();
            for seq in 0..PER_STREAM {
                for sid in 0..STREAMS {
                    burst.push(sample(sid, seq));
                }
                if seq % 7 == 0 {
                    svc.submit_batch(std::mem::take(&mut burst)).unwrap();
                }
            }
            svc.submit_batch(burst).unwrap();
        } else {
            for seq in 0..PER_STREAM {
                for sid in 0..STREAMS {
                    svc.submit(sample(sid, seq)).unwrap();
                }
            }
        }
        index(svc.finish().unwrap())
    };
    let single = run(false);
    let batched = run(true);
    assert_eq!(single.len(), batched.len());
    for (key, a) in &single {
        assert_eq!(
            key_fields(a),
            key_fields(&batched[key]),
            "verdict diverged at {key:?}"
        );
    }
}

#[test]
fn runs_split_across_migrations_stay_bit_identical() {
    // Bursts of ONE long same-stream run each: the worker's coalescer
    // sees maximal runs, and a migration landing mid-stream splits some
    // run between the old owner (processed pre-seal), the stray path,
    // and the new owner (stash → adopt replay). Every verdict must
    // still match the scalar reference recurrence bit-for-bit.
    const RUN: u64 = 50;
    let svc = Service::start(cfg(3, 64)).unwrap();
    std::thread::scope(|scope| {
        for t in 0..THREADS {
            let handle = svc.handle();
            scope.spawn(move || {
                let sids: Vec<u64> =
                    (0..STREAMS).filter(|sid| sid % THREADS == t).collect();
                for start in (0..PER_STREAM).step_by(RUN as usize) {
                    for &sid in &sids {
                        let burst: Vec<Sample> = (start
                            ..(start + RUN).min(PER_STREAM))
                            .map(|seq| sample(sid, seq))
                            .collect();
                        handle.submit_batch(burst).unwrap();
                    }
                }
            });
        }
        // Ping-pong every shard between workers 0 and 1 while the long
        // runs stream in (worker 2 keeps its own share throughout).
        let pause = Duration::from_millis(2);
        for flip in 0..6u32 {
            std::thread::sleep(pause);
            let from = (flip % 2) as usize;
            let moves: Vec<(u32, usize)> = svc
                .table()
                .shards_on(from)
                .into_iter()
                .map(|s| (s, 1 - from))
                .collect();
            svc.migrate_shards(&moves).unwrap();
        }
    });
    let metrics = svc.metrics();
    let stale = metrics.stale_drops.get();
    let map = index(svc.finish().unwrap());
    if stale > 0 {
        // A counted late-stray drop leaves a gap in that stream's
        // recurrence, so the full-history oracle no longer applies;
        // the coverage contract is the lenient one (see the scaling
        // test above).
        assert!(
            map.len() as u64 >= STREAMS * PER_STREAM - stale,
            "lost more verdicts than counted stale drops"
        );
        return;
    }
    // Oracle: the scalar f64 reference recurrence, per stream, in seq
    // order — what the software engine must compute no matter how the
    // runs were split across workers, stashes, and replays.
    for sid in 0..STREAMS {
        let mut det = teda_fpga::teda::TedaDetector::new(2, 3.0);
        for seq in 0..PER_STREAM {
            let v = det.step(&sample(sid, seq).values);
            let got = map
                .get(&(sid, seq))
                .unwrap_or_else(|| panic!("verdict lost at ({sid}, {seq})"));
            assert_eq!(
                key_fields(got),
                (v.k, v.outlier, v.zeta.to_bits(), v.threshold.to_bits()),
                "verdict diverged at ({sid}, {seq})"
            );
        }
    }
}

#[test]
fn queue_capacity_one_is_lossless() {
    // The smallest legal queues: every second push hits the full-ring
    // backpressure path, and batches always overflow to blocking ctl
    // sends. Nothing may be dropped.
    let svc = Service::start(cfg(2, 1)).unwrap();
    let metrics = svc.metrics();
    for seq in 0..125u64 {
        for sid in 0..4u64 {
            if seq % 2 == 0 {
                svc.submit(sample(sid, seq)).unwrap();
            } else {
                svc.submit_batch(vec![sample(sid, seq)]).unwrap();
            }
        }
    }
    let out = svc.finish().unwrap();
    assert_eq!(out.len(), 500);
    assert_eq!(metrics.samples_in.get(), 500);
    for c in &out {
        assert_eq!(c.verdict.k, c.verdict.seq + 1, "stream state corrupted");
    }
}

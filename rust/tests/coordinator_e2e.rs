//! End-to-end coordinator integration: sources → router → workers →
//! engines → verdicts, across all three backends, with the same
//! correctness bar (every sample classified exactly once, per-stream
//! order preserved, detections match the oracle).

use std::collections::BTreeMap;

use teda_fpga::config::{EngineKind, ServiceConfig};
use teda_fpga::coordinator::Service;
use teda_fpga::damadics::{schedule_item, ActuatorSim};
use teda_fpga::engine::EngineVerdict;
use teda_fpga::stream::{ReplaySource, Sample, StreamSource, SyntheticSource};
use teda_fpga::teda::TedaDetector;
use teda_fpga::util::propkit::forall;

fn artifacts_present() -> bool {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    std::path::Path::new(dir).join("manifest.json").exists()
}

fn cfg(engine: EngineKind, workers: usize) -> ServiceConfig {
    ServiceConfig {
        engine,
        workers,
        n_features: 2,
        queue_capacity: 128,
        artifact_dir: concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts").into(),
        ..Default::default()
    }
}

/// Drive `streams`×`per_stream` synthetic samples through a service and
/// index verdicts by (stream, seq), asserting exactly-once delivery.
fn drive(
    engine: EngineKind,
    workers: usize,
    streams: u64,
    per_stream: usize,
) -> BTreeMap<(u64, u64), EngineVerdict> {
    let svc = Service::start(cfg(engine, workers)).unwrap();
    let mut sources: Vec<SyntheticSource> = (0..streams)
        .map(|sid| SyntheticSource::new(sid, 2, per_stream, 42))
        .collect();
    // Round-robin interleave, as a fair multi-stream ingress would.
    loop {
        let mut any = false;
        for src in &mut sources {
            if let Some(s) = src.next_sample() {
                svc.submit(s).unwrap();
                any = true;
            }
        }
        if !any {
            break;
        }
    }
    let out = svc.finish().unwrap();
    let mut map = BTreeMap::new();
    for c in out {
        let key = (c.verdict.stream_id, c.verdict.seq);
        assert!(map.insert(key, c.verdict).is_none(), "duplicate {key:?}");
    }
    assert_eq!(map.len(), streams as usize * per_stream);
    map
}

#[test]
fn software_service_end_to_end() {
    let out = drive(EngineKind::Software, 4, 8, 100);
    // Verdicts must equal a direct per-stream detector run.
    for sid in 0..8u64 {
        let mut det = TedaDetector::new(2, 3.0);
        let mut src = SyntheticSource::new(sid, 2, 100, 42);
        while let Some(s) = src.next_sample() {
            let v = det.step(&s.values);
            let got = &out[&(sid, s.seq)];
            assert_eq!(got.k, v.k);
            assert_eq!(got.outlier, v.outlier);
            assert!((got.zeta - v.zeta).abs() < 1e-12);
        }
    }
}

#[test]
fn rtl_service_end_to_end() {
    let out = drive(EngineKind::Rtl, 3, 5, 80);
    // Flags must match the f64 oracle away from k=1.
    for sid in 0..5u64 {
        let mut det = TedaDetector::new(2, 3.0);
        let mut src = SyntheticSource::new(sid, 2, 80, 42);
        while let Some(s) = src.next_sample() {
            let v = det.step(&s.values);
            let got = &out[&(sid, s.seq)];
            assert_eq!(got.k, v.k);
            if v.k > 1 {
                assert_eq!(got.outlier, v.outlier, "sid={sid} k={}", v.k);
            }
        }
    }
}

#[test]
fn xla_service_end_to_end() {
    if !artifacts_present() {
        eprintln!("artifacts missing — skipping XLA e2e");
        return;
    }
    // 2 workers only: each builds its own PJRT runtime.
    let out = drive(EngineKind::Xla, 2, 6, 70);
    let mut flag_diffs = 0usize;
    for sid in 0..6u64 {
        let mut det = TedaDetector::new(2, 3.0);
        let mut src = SyntheticSource::new(sid, 2, 70, 42);
        while let Some(s) = src.next_sample() {
            let v = det.step(&s.values);
            let got = &out[&(sid, s.seq)];
            assert_eq!(got.k, v.k, "sid={sid} seq={}", s.seq);
            if got.outlier != v.outlier {
                flag_diffs += 1; // f32 vs f64 threshold edges only
            }
        }
    }
    assert!(flag_diffs <= 4, "too many flag diffs: {flag_diffs}");
}

#[test]
fn damadics_day_through_service_detects_fault() {
    // The Fig. 6 workload run through the full service instead of a
    // bare detector: fault item 1 must still be caught.
    let event = schedule_item(1).unwrap();
    let trace = ActuatorSim::with_seed(2001).generate_day(Some(&event));
    let svc = Service::start(cfg(EngineKind::Software, 2)).unwrap();
    let mut src = ReplaySource::new(0, trace);
    while let Some(s) = src.next_sample() {
        svc.submit(s).unwrap();
    }
    let metrics = svc.metrics();
    let out = svc.finish().unwrap();
    assert_eq!(out.len(), 86_400);
    let hits = out
        .iter()
        .filter(|c| c.verdict.outlier && event.contains(c.verdict.seq as usize))
        .count();
    assert!(hits > 0, "fault not detected through the service");
    assert_eq!(metrics.verdicts_out.get(), 86_400);
    assert!(metrics.outliers.get() >= hits as u64);
}

#[test]
fn prop_service_exactly_once_any_topology() {
    forall("service exactly-once", 6, |g| {
        let workers = g.usize_in(1, 6);
        let streams = g.usize_in(1, 10) as u64;
        let per_stream = g.usize_in(1, 60);
        let map = drive(EngineKind::Software, workers, streams, per_stream);
        // Sequences are contiguous per stream.
        for sid in 0..streams {
            for seq in 0..per_stream as u64 {
                assert!(map.contains_key(&(sid, seq)), "missing {sid}/{seq}");
            }
        }
    });
}

#[test]
fn backpressure_blocks_but_loses_nothing() {
    // Tiny queues force the backpressure path; every sample must still
    // come back exactly once.
    let mut c = cfg(EngineKind::Software, 2);
    c.queue_capacity = 2;
    let svc = Service::start(c).unwrap();
    for seq in 0..2000u64 {
        for sid in 0..4u64 {
            svc.submit(Sample {
                stream_id: sid,
                seq,
                values: vec![0.4, 0.6],
            })
            .unwrap();
        }
    }
    let metrics = svc.metrics();
    let out = svc.finish().unwrap();
    assert_eq!(out.len(), 8000);
    // With capacity 2 and 8000 fast submits, blocking must have happened.
    assert!(metrics.backpressure_events.get() > 0);
}

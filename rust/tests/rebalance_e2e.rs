//! Elastic-sharding end-to-end: a service is subjected to forced
//! mid-stream shard migrations AND live worker resizes (`scale_to` up
//! and back down), and its verdicts must equal an undisturbed run
//! verdict-for-verdict, bit-for-bit — for every `EngineKind`, including
//! an ensemble with an RTL member (open fusion quorums cross the
//! migration) and adaptive per-stream weights.
//!
//! The migration protocol under test: Expect → table swap (epoch + 1) →
//! Seal (snapshot every resident stream at its watermark, encoded
//! through the persist codec) → barrier → stray re-route → Adopt
//! (restore + stash replay through the inclusive-watermark dedup).

use std::collections::BTreeMap;

use teda_fpga::config::{
    CombinerKind, EngineKind, EnsembleConfig, ServiceConfig, ShardingConfig,
};
use teda_fpga::coordinator::Service;
use teda_fpga::engine::EngineVerdict;
use teda_fpga::stream::Sample;
use teda_fpga::util::prng::SplitMix64;

const STREAMS: u64 = 6;
const PER_STREAM: u64 = 90;
/// Migrate every shard off stream 0's worker after this seq...
const MIGRATE_AT: u64 = 30;
/// ...grow the pool here...
const SCALE_UP_AT: u64 = 50;
/// ...and shrink it below the starting size here.
const SCALE_DOWN_AT: u64 = 70;

fn artifacts_present() -> bool {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    std::path::Path::new(dir).join("manifest.json").exists()
}

fn cfg(engine: EngineKind) -> ServiceConfig {
    ServiceConfig {
        engine,
        workers: 3,
        n_features: 2,
        queue_capacity: 256,
        artifact_dir: concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")
            .into(),
        // Small shard space keeps per-worker shard lists readable in
        // failures; rebalancing math is identical at any size.
        sharding: ShardingConfig {
            virtual_shards: 32,
            ..Default::default()
        },
        // RTL member gives the ensemble open quorums at every migration
        // point; its tighter threshold (m=1.5 vs 3) makes it disagree
        // often, so the adaptive combiner's per-stream weights genuinely
        // evolve — quorums and learned weights must both migrate intact.
        ensemble: EnsembleConfig::from_member_list(
            "teda:m=3+rtl:m=1.5",
            CombinerKind::Adaptive,
        )
        .unwrap(),
        ..Default::default()
    }
}

/// Deterministic per-(stream, seq) sample so all runs see identical
/// input without sharing RNG state across services.
fn sample(sid: u64, seq: u64) -> Sample {
    let mut rng = SplitMix64::new(sid.wrapping_mul(0x9E37) ^ seq);
    Sample {
        stream_id: sid,
        seq,
        values: vec![rng.uniform(-2.0, 2.0), rng.uniform(-2.0, 2.0)],
    }
}

fn index(
    out: Vec<teda_fpga::coordinator::Classified>,
    map: &mut BTreeMap<(u64, u64), EngineVerdict>,
) {
    for c in out {
        let key = (c.verdict.stream_id, c.verdict.seq);
        match map.get(&key) {
            // Replay duplicates must be IDENTICAL re-derivations
            // (NaN-safe: bit-compare the observables).
            Some(prev) => {
                assert_eq!(prev.k, c.verdict.k, "{key:?}");
                assert_eq!(prev.outlier, c.verdict.outlier, "{key:?}");
                assert_eq!(
                    prev.zeta.to_bits(),
                    c.verdict.zeta.to_bits(),
                    "replayed verdict diverged at {key:?}"
                );
            }
            None => {
                map.insert(key, c.verdict);
            }
        }
    }
}

fn run_uninterrupted(
    engine: EngineKind,
) -> BTreeMap<(u64, u64), EngineVerdict> {
    let svc = Service::start(cfg(engine)).unwrap();
    for seq in 0..PER_STREAM {
        for sid in 0..STREAMS {
            svc.submit(sample(sid, seq)).unwrap();
        }
    }
    let mut map = BTreeMap::new();
    index(svc.finish().unwrap(), &mut map);
    map
}

fn run_with_churn(engine: EngineKind) -> BTreeMap<(u64, u64), EngineVerdict> {
    let svc = Service::start(cfg(engine)).unwrap();
    let metrics = svc.metrics();
    for seq in 0..PER_STREAM {
        for sid in 0..STREAMS {
            svc.submit(sample(sid, seq)).unwrap();
        }
        match seq {
            MIGRATE_AT => {
                // Whoever owns stream 0 definitely has resident state —
                // the seal → adopt handoff moves real snapshots.
                let table = svc.table();
                let donor = table.route(0).0;
                let to = (donor + 1) % table.workers();
                let moves: Vec<(u32, usize)> = table
                    .shards_on(donor)
                    .into_iter()
                    .map(|s| (s, to))
                    .collect();
                assert!(!moves.is_empty());
                svc.migrate_shards(&moves).unwrap();
                assert!(
                    svc.table().shards_on(donor).is_empty(),
                    "donor must be emptied"
                );
            }
            SCALE_UP_AT => {
                svc.scale_to(5).unwrap();
                assert_eq!(svc.workers(), 5);
                assert_eq!(svc.table().workers(), 5);
            }
            SCALE_DOWN_AT => {
                svc.scale_to(2).unwrap();
                assert_eq!(svc.workers(), 2);
            }
            _ => {}
        }
    }
    assert!(metrics.migrations.get() >= 3, "forced churn must migrate");
    assert!(metrics.streams_migrated.get() >= 1);
    assert!(svc.table().epoch() > 0, "churn must advance the epoch");
    assert_eq!(metrics.epoch.get(), svc.table().epoch());
    assert_eq!(metrics.workers_active.get(), 2);
    let mut map = BTreeMap::new();
    index(svc.finish().unwrap(), &mut map);
    map
}

fn assert_churn_invisible(engine: EngineKind) {
    let full = run_uninterrupted(engine);
    let churned = run_with_churn(engine);
    assert_eq!(
        full.len(),
        (STREAMS * PER_STREAM) as usize,
        "{engine}: uninterrupted run must classify everything"
    );
    assert_eq!(
        churned.len(),
        full.len(),
        "{engine}: churn lost or duplicated verdicts"
    );
    for (key, a) in &full {
        let b = &churned[key];
        assert_eq!(a.k, b.k, "{engine} {key:?}");
        assert_eq!(a.outlier, b.outlier, "{engine} {key:?}");
        assert_eq!(
            a.zeta.to_bits(),
            b.zeta.to_bits(),
            "{engine} {key:?}: zeta {} vs {}",
            a.zeta,
            b.zeta
        );
        assert_eq!(a.threshold.to_bits(), b.threshold.to_bits());
    }
}

#[test]
fn software_migrations_and_resize_are_invisible() {
    assert_churn_invisible(EngineKind::Software);
}

#[test]
fn rtl_migrations_and_resize_are_invisible() {
    // The RTL pipeline has 2-cycle latency: every migration point has
    // in-flight verdicts that must travel inside the register-file
    // snapshot and re-emerge on the new worker.
    assert_churn_invisible(EngineKind::Rtl);
}

#[test]
fn ensemble_migrations_and_resize_are_invisible() {
    assert_churn_invisible(EngineKind::Ensemble);
}

#[test]
fn xla_migrations_and_resize_are_invisible() {
    if !artifacts_present() {
        eprintln!("artifacts missing — skipping XLA rebalance e2e");
        return;
    }
    assert_churn_invisible(EngineKind::Xla);
}

#[test]
fn migration_composes_with_checkpoint_failover() {
    // Sharding and checkpointing share the watermark semantics: migrate
    // mid-stream, then kill the service and fail over from checkpoints —
    // the union of verdicts still equals the undisturbed run.
    let mut c = cfg(EngineKind::Software);
    c.checkpoint_every = 20;
    c.restore_on_resume = true;
    let full = run_uninterrupted(EngineKind::Software);

    let svc = Service::start(c.clone()).unwrap();
    let state = svc.state_manager();
    for seq in 0..55u64 {
        for sid in 0..STREAMS {
            svc.submit(sample(sid, seq)).unwrap();
        }
        if seq == MIGRATE_AT {
            let table = svc.table();
            let donor = table.route(0).0;
            let to = (donor + 1) % table.workers();
            let moves: Vec<(u32, usize)> = table
                .shards_on(donor)
                .into_iter()
                .map(|s| (s, to))
                .collect();
            svc.migrate_shards(&moves).unwrap();
        }
    }
    let mut map = BTreeMap::new();
    index(svc.abort().unwrap(), &mut map);
    // Every stream has a checkpoint at ≥ the periodic watermark (the
    // migration seal publishes at the exact last-processed seq, which
    // can be newer).
    let mut resume = u64::MAX;
    for sid in 0..STREAMS {
        let cp = state.latest(sid).expect("checkpoint before the kill");
        assert!(cp.seq >= 39, "stream {sid} watermark {}", cp.seq);
        resume = resume.min(cp.seq + 1);
    }
    let svc2 = Service::start_with_state(c, state).unwrap();
    for seq in resume..PER_STREAM {
        for sid in 0..STREAMS {
            svc2.submit(sample(sid, seq)).unwrap();
        }
    }
    index(svc2.finish().unwrap(), &mut map);
    assert_eq!(map.len(), full.len());
    for (key, a) in &full {
        let b = &map[key];
        assert_eq!((a.k, a.outlier), (b.k, b.outlier), "{key:?}");
        assert_eq!(a.zeta.to_bits(), b.zeta.to_bits(), "{key:?}");
    }
}

#[test]
fn concurrent_submitter_survives_churn_bit_exactly() {
    // A separate submitter thread hammers the service through a
    // ServiceHandle while the main thread migrates shards and resizes
    // the pool underneath it. Stale routing snapshots are expected —
    // strays are re-routed, stash replays re-sort by (stream, seq) —
    // and the result must STILL be verdict-for-verdict bit-identical
    // to an undisturbed run.
    const CSTREAMS: u64 = 8;
    const CPER: u64 = 400;
    let submit_all = |svc: &Service| {
        for seq in 0..CPER {
            for sid in 0..CSTREAMS {
                svc.submit(sample(sid, seq)).unwrap();
            }
        }
    };
    let svc = Service::start(cfg(EngineKind::Software)).unwrap();
    submit_all(&svc);
    let mut reference = BTreeMap::new();
    index(svc.finish().unwrap(), &mut reference);

    let svc = Service::start(cfg(EngineKind::Software)).unwrap();
    let metrics = svc.metrics();
    let handle = svc.handle();
    let feeder = std::thread::spawn(move || {
        for seq in 0..CPER {
            for sid in 0..CSTREAMS {
                handle.submit(sample(sid, seq)).unwrap();
            }
        }
    });
    for round in 0..6usize {
        std::thread::sleep(std::time::Duration::from_millis(2));
        let table = svc.table();
        let donor = round % table.workers();
        let to = (donor + 1) % table.workers();
        let moves: Vec<(u32, usize)> = table
            .shards_on(donor)
            .into_iter()
            .map(|s| (s, to))
            .collect();
        svc.migrate_shards(&moves).unwrap();
        if round == 2 {
            svc.scale_to(4).unwrap();
        }
        if round == 4 {
            svc.scale_to(3).unwrap();
        }
    }
    feeder.join().expect("submitter thread");
    assert!(metrics.migrations.get() >= 6);
    let mut churned = BTreeMap::new();
    index(svc.finish().unwrap(), &mut churned);
    // The watermark guard only fires if the OS preempts the feeder
    // mid-submit across an ENTIRE migration (two rendezvous) — the
    // documented pathological case, in which one verdict per hit is
    // dropped rather than ingested out of order and that stream's
    // later verdicts legitimately differ. In every realistic schedule
    // it stays 0 and the run must be loss-free and bit-identical.
    let dropped = metrics.stale_drops.get();
    if dropped == 0 {
        assert_eq!(
            churned.len(),
            reference.len(),
            "lost/duplicated verdicts"
        );
        for (key, a) in &reference {
            let b = &churned[key];
            assert_eq!(a.k, b.k, "{key:?}");
            assert_eq!(a.zeta.to_bits(), b.zeta.to_bits(), "{key:?}");
        }
    } else {
        eprintln!(
            "note: {dropped} stray(s) outlived a whole migration and \
             were dropped by the watermark guard — skipping the strict \
             bit-compare for this schedule"
        );
        assert!(
            churned.len() as u64 + dropped >= reference.len() as u64,
            "verdicts lost beyond the guarded drops"
        );
    }
}

#[test]
fn migrating_to_the_same_worker_is_a_noop() {
    let svc = Service::start(cfg(EngineKind::Software)).unwrap();
    for seq in 0..10u64 {
        for sid in 0..STREAMS {
            svc.submit(sample(sid, seq)).unwrap();
        }
    }
    let table = svc.table();
    let shard = table.shard_of(0);
    let owner = table.worker_of(shard);
    svc.migrate_shards(&[(shard, owner)]).unwrap();
    assert_eq!(svc.table().epoch(), 0, "self-moves must not churn");
    assert_eq!(svc.metrics().migrations.get(), 0);
    svc.finish().unwrap();
}

#[test]
fn invalid_migrations_are_rejected() {
    let svc = Service::start(cfg(EngineKind::Software)).unwrap();
    assert!(svc.migrate_shards(&[(9999, 0)]).is_err(), "bad shard");
    assert!(svc.migrate_shards(&[(0, 99)]).is_err(), "bad worker");
    svc.finish().unwrap();
}

//! Property: checkpoint/restore is invisible. For EVERY prefix length
//! of a random stream, snapshotting after the prefix, restoring into a
//! fresh engine, and feeding the remainder yields verdicts identical to
//! the uninterrupted run — for the software, RTL, and single-member
//! ensemble engines. This is the failover correctness property at the
//! engine level; `failover_e2e` proves the same through the service.

use std::collections::BTreeMap;

use teda_fpga::config::{CombinerKind, EnsembleConfig};
use teda_fpga::engine::{Engine, EngineVerdict, RtlEngine, SoftwareEngine};
use teda_fpga::ensemble::EnsembleEngine;
use teda_fpga::stream::Sample;
use teda_fpga::util::propkit::{forall, Gen};

/// NaN-safe verdict equality (the RTL ζ₁ is NaN by design): identical
/// bit patterns, not IEEE `==`.
fn assert_verdicts_eq(
    a: &BTreeMap<(u64, u64), EngineVerdict>,
    b: &BTreeMap<(u64, u64), EngineVerdict>,
    ctx: &str,
) {
    assert_eq!(a.len(), b.len(), "{ctx}: verdict count");
    for (key, va) in a {
        let vb = b.get(key).unwrap_or_else(|| panic!("{ctx}: missing {key:?}"));
        assert_eq!(va.k, vb.k, "{ctx} {key:?}");
        assert_eq!(va.outlier, vb.outlier, "{ctx} {key:?}");
        assert_eq!(
            va.zeta.to_bits(),
            vb.zeta.to_bits(),
            "{ctx} {key:?}: zeta {} vs {}",
            va.zeta,
            vb.zeta
        );
        assert_eq!(
            va.threshold.to_bits(),
            vb.threshold.to_bits(),
            "{ctx} {key:?}"
        );
        assert_eq!(
            va.eccentricity.to_bits(),
            vb.eccentricity.to_bits(),
            "{ctx} {key:?}"
        );
    }
}

fn collect(
    map: &mut BTreeMap<(u64, u64), EngineVerdict>,
    verdicts: Vec<EngineVerdict>,
) {
    for v in verdicts {
        let key = (v.stream_id, v.seq);
        assert!(map.insert(key, v).is_none(), "duplicate verdict {key:?}");
    }
}

/// The property itself, generic over an engine constructor.
fn snapshot_at_every_prefix_is_invisible(
    g: &mut Gen,
    make: &dyn Fn() -> Box<dyn Engine>,
    label: &str,
) {
    let sid = g.u64_below(1000);
    let len = g.usize_in(4, 28);
    let samples: Vec<Sample> = (0..len)
        .map(|seq| Sample {
            stream_id: sid,
            seq: seq as u64,
            values: vec![g.f64_in(-2.0, 2.0), g.f64_in(-2.0, 2.0)],
        })
        .collect();

    // Uninterrupted oracle.
    let mut oracle = make();
    let mut full = BTreeMap::new();
    for s in &samples {
        collect(&mut full, oracle.ingest(s).unwrap());
    }
    collect(&mut full, oracle.flush().unwrap());
    assert_eq!(full.len(), len, "{label}: every sample classified");

    for cut in 0..len {
        let mut live = make();
        let mut got = BTreeMap::new();
        for s in &samples[..cut] {
            collect(&mut got, live.ingest(s).unwrap());
        }
        let mut restored = make();
        if let Some(snap) = live.snapshot(sid) {
            restored.restore(sid, snap).unwrap();
        }
        for s in &samples[cut..] {
            collect(&mut got, restored.ingest(s).unwrap());
        }
        collect(&mut got, restored.flush().unwrap());
        assert_verdicts_eq(&got, &full, &format!("{label} cut={cut}"));
    }
}

#[test]
fn prop_software_snapshot_restore_at_every_prefix() {
    forall("software snapshot ≡ uninterrupted", 24, |g| {
        let m = g.f64_in(1.5, 4.5);
        snapshot_at_every_prefix_is_invisible(
            g,
            &move || Box::new(SoftwareEngine::new(2, m)),
            "software",
        );
    });
}

#[test]
fn prop_rtl_snapshot_restore_at_every_prefix() {
    forall("rtl snapshot ≡ uninterrupted", 12, |g| {
        let m = g.f64_in(1.5, 4.5);
        snapshot_at_every_prefix_is_invisible(
            g,
            &move || Box::new(RtlEngine::new(2, m)),
            "rtl",
        );
    });
}

#[test]
fn prop_single_member_ensemble_snapshot_restore_at_every_prefix() {
    forall("ensemble snapshot ≡ uninterrupted", 12, |g| {
        let m = g.f64_in(1.5, 4.5);
        // Adaptive combiner so per-stream learned weights are part of
        // what the snapshot must carry.
        let cfg = EnsembleConfig::from_member_list(
            &format!("teda:m={m}"),
            CombinerKind::Adaptive,
        )
        .unwrap();
        snapshot_at_every_prefix_is_invisible(
            g,
            &move || Box::new(EnsembleEngine::new(&cfg, 2).unwrap()),
            "ensemble",
        );
    });
}

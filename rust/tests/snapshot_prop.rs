//! Property: checkpoint/restore is invisible. For EVERY prefix length
//! of a random stream, snapshotting after the prefix, restoring into a
//! fresh engine, and feeding the remainder yields verdicts identical to
//! the uninterrupted run — for the software, RTL, and single-member
//! ensemble engines. This is the failover correctness property at the
//! engine level; `failover_e2e` proves the same through the service.
//!
//! The `*_through_codec` variants strengthen the property for durable
//! persistence: the snapshot additionally round-trips through the
//! versioned binary codec (`decode(encode(snapshot))`) before the
//! restore, so serialize → deserialize → restore is verdict-for-verdict
//! identical to the live-snapshot path at every prefix.

use std::collections::BTreeMap;

use teda_fpga::config::{CombinerKind, EnsembleConfig};
use teda_fpga::coordinator::StateCheckpoint;
use teda_fpga::engine::{Engine, EngineVerdict, RtlEngine, SoftwareEngine};
use teda_fpga::ensemble::EnsembleEngine;
use teda_fpga::persist::codec;
use teda_fpga::stream::Sample;
use teda_fpga::util::propkit::{forall, Gen};

/// NaN-safe verdict equality (the RTL ζ₁ is NaN by design): identical
/// bit patterns, not IEEE `==`.
fn assert_verdicts_eq(
    a: &BTreeMap<(u64, u64), EngineVerdict>,
    b: &BTreeMap<(u64, u64), EngineVerdict>,
    ctx: &str,
) {
    assert_eq!(a.len(), b.len(), "{ctx}: verdict count");
    for (key, va) in a {
        let vb = b.get(key).unwrap_or_else(|| panic!("{ctx}: missing {key:?}"));
        assert_eq!(va.k, vb.k, "{ctx} {key:?}");
        assert_eq!(va.outlier, vb.outlier, "{ctx} {key:?}");
        assert_eq!(
            va.zeta.to_bits(),
            vb.zeta.to_bits(),
            "{ctx} {key:?}: zeta {} vs {}",
            va.zeta,
            vb.zeta
        );
        assert_eq!(
            va.threshold.to_bits(),
            vb.threshold.to_bits(),
            "{ctx} {key:?}"
        );
        assert_eq!(
            va.eccentricity.to_bits(),
            vb.eccentricity.to_bits(),
            "{ctx} {key:?}"
        );
    }
}

fn collect(
    map: &mut BTreeMap<(u64, u64), EngineVerdict>,
    verdicts: Vec<EngineVerdict>,
) {
    for v in verdicts {
        let key = (v.stream_id, v.seq);
        assert!(map.insert(key, v).is_none(), "duplicate verdict {key:?}");
    }
}

/// The property itself, generic over an engine constructor. With
/// `through_codec`, every snapshot is encoded to bytes and decoded
/// back before the restore — the durable-persistence path.
fn snapshot_at_every_prefix_is_invisible_inner(
    g: &mut Gen,
    make: &dyn Fn() -> Box<dyn Engine>,
    label: &str,
    through_codec: bool,
) {
    let sid = g.u64_below(1000);
    let len = g.usize_in(4, 28);
    let samples: Vec<Sample> = (0..len)
        .map(|seq| Sample {
            stream_id: sid,
            seq: seq as u64,
            values: vec![g.f64_in(-2.0, 2.0), g.f64_in(-2.0, 2.0)],
        })
        .collect();

    // Uninterrupted oracle.
    let mut oracle = make();
    let mut full = BTreeMap::new();
    for s in &samples {
        collect(&mut full, oracle.ingest(s).unwrap());
    }
    collect(&mut full, oracle.flush().unwrap());
    assert_eq!(full.len(), len, "{label}: every sample classified");

    for cut in 0..len {
        let mut live = make();
        let mut got = BTreeMap::new();
        for s in &samples[..cut] {
            collect(&mut got, live.ingest(s).unwrap());
        }
        let mut restored = make();
        if let Some(snap) = live.snapshot(sid) {
            let snap = if through_codec {
                let cp = StateCheckpoint {
                    stream_id: sid,
                    seq: cut as u64 - 1,
                    snapshot: snap,
                };
                let encoded = codec::encode(&cp);
                let decoded =
                    codec::decode(&encoded).unwrap_or_else(|e| {
                        panic!("{label} cut={cut}: decode failed: {e}")
                    });
                // Bit-exact round trip: re-encoding the decoded record
                // reproduces the original bytes. (Byte comparison, not
                // `==` on the structs — RTL register files legitimately
                // hold NaN wires around k = 1, and NaN != NaN would
                // fail a structural compare that is in fact exact.)
                assert_eq!(
                    codec::encode(&decoded),
                    encoded,
                    "{label} cut={cut}: re-encode diverged"
                );
                decoded.snapshot
            } else {
                snap
            };
            restored.restore(sid, snap).unwrap();
        }
        for s in &samples[cut..] {
            collect(&mut got, restored.ingest(s).unwrap());
        }
        collect(&mut got, restored.flush().unwrap());
        assert_verdicts_eq(&got, &full, &format!("{label} cut={cut}"));
    }
}

fn snapshot_at_every_prefix_is_invisible(
    g: &mut Gen,
    make: &dyn Fn() -> Box<dyn Engine>,
    label: &str,
) {
    snapshot_at_every_prefix_is_invisible_inner(g, make, label, false);
}

fn codec_roundtrip_at_every_prefix_is_invisible(
    g: &mut Gen,
    make: &dyn Fn() -> Box<dyn Engine>,
    label: &str,
) {
    snapshot_at_every_prefix_is_invisible_inner(g, make, label, true);
}

#[test]
fn prop_software_snapshot_restore_at_every_prefix() {
    forall("software snapshot ≡ uninterrupted", 24, |g| {
        let m = g.f64_in(1.5, 4.5);
        snapshot_at_every_prefix_is_invisible(
            g,
            &move || Box::new(SoftwareEngine::new(2, m)),
            "software",
        );
    });
}

#[test]
fn prop_rtl_snapshot_restore_at_every_prefix() {
    forall("rtl snapshot ≡ uninterrupted", 12, |g| {
        let m = g.f64_in(1.5, 4.5);
        snapshot_at_every_prefix_is_invisible(
            g,
            &move || Box::new(RtlEngine::new(2, m)),
            "rtl",
        );
    });
}

#[test]
fn prop_single_member_ensemble_snapshot_restore_at_every_prefix() {
    forall("ensemble snapshot ≡ uninterrupted", 12, |g| {
        let m = g.f64_in(1.5, 4.5);
        // Adaptive combiner so per-stream learned weights are part of
        // what the snapshot must carry.
        let cfg = EnsembleConfig::from_member_list(
            &format!("teda:m={m}"),
            CombinerKind::Adaptive,
        )
        .unwrap();
        snapshot_at_every_prefix_is_invisible(
            g,
            &move || Box::new(EnsembleEngine::new(&cfg, 2).unwrap()),
            "ensemble",
        );
    });
}

#[test]
fn prop_software_codec_roundtrip_at_every_prefix() {
    forall("software decode(encode) ≡ live snapshot", 16, |g| {
        let m = g.f64_in(1.5, 4.5);
        codec_roundtrip_at_every_prefix_is_invisible(
            g,
            &move || Box::new(SoftwareEngine::new(2, m)),
            "software+codec",
        );
    });
}

#[test]
fn prop_rtl_codec_roundtrip_at_every_prefix() {
    forall("rtl decode(encode) ≡ live snapshot", 8, |g| {
        let m = g.f64_in(1.5, 4.5);
        codec_roundtrip_at_every_prefix_is_invisible(
            g,
            &move || Box::new(RtlEngine::new(2, m)),
            "rtl+codec",
        );
    });
}

#[test]
fn prop_heterogeneous_ensemble_codec_roundtrip_at_every_prefix() {
    // Full-roster ensemble: TEDA software + RTL (open quorums at every
    // cut — the RTL member is 2 samples late) + both baseline families,
    // under the adaptive combiner. This exercises every MemberSnapshot
    // variant and the pending-vote encoding in one property.
    forall("ensemble decode(encode) ≡ live snapshot", 6, |g| {
        let m = g.f64_in(1.5, 4.5);
        let cfg = EnsembleConfig::from_member_list(
            &format!("teda:m={m}+rtl:m={m}+msigma:m=3+zscore:m=3,w=8"),
            CombinerKind::Adaptive,
        )
        .unwrap();
        codec_roundtrip_at_every_prefix_is_invisible(
            g,
            &move || Box::new(EnsembleEngine::new(&cfg, 2).unwrap()),
            "ensemble+codec",
        );
    });
}

#[test]
fn prop_xla_codec_roundtrip_at_every_prefix() {
    // The XLA engine needs AOT artifacts; skip (like every XLA test)
    // when they are absent. The codec's XlaSnapshot coverage does not
    // depend on this test alone: persist::codec has artifact-free
    // synthetic round-trip tests for the variant.
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !std::path::Path::new(dir).join("manifest.json").exists() {
        eprintln!("artifacts missing; skipping XLA codec prop");
        return;
    }
    forall("xla decode(encode) ≡ live snapshot", 4, |g| {
        let rt = teda_fpga::runtime::XlaRuntime::new(dir).unwrap();
        codec_roundtrip_at_every_prefix_is_invisible(
            g,
            &move || {
                Box::new(
                    teda_fpga::engine::XlaEngine::new(&rt, 2, 1).unwrap(),
                )
            },
            "xla+codec",
        );
    });
}

//! Corruption battery for the cluster wire format.
//!
//! The framing contract under attack (the network twin of
//! `persist_corruption.rs`): bit flips, truncations, oversized length
//! prefixes, count bombs, and mid-stream disconnects must ALWAYS yield
//! a clean decode error or a clean disconnect — never a panic, never a
//! silently different message, never an attacker-sized allocation.

use std::io::{Cursor, Write};
use std::net::{TcpListener, TcpStream};
use std::thread;

use teda_fpga::coordinator::transport::frame::{
    self, Msg, HEADER_LEN, MAGIC, MAX_PAYLOAD, READ_TIMEOUT, VERSION,
};
use teda_fpga::persist::codec::crc32;
use teda_fpga::stream::Sample;
use teda_fpga::util::prng::SplitMix64;
use teda_fpga::Result;

fn sample(sid: u64, seq: u64) -> Sample {
    Sample { stream_id: sid, seq, values: vec![0.5, -1.25, 3.0] }
}

/// One representative of every wire message, non-trivial payloads.
fn every_msg() -> Vec<Msg> {
    vec![
        Msg::Hello { node_id: 1, epoch: 0 },
        Msg::Heartbeat { node_id: 2, epoch: 7, load: 4_096 },
        Msg::Join { node_id: 3, addr: "10.0.0.3:7000".into() },
        Msg::Leave { node_id: 3 },
        Msg::JoinOk {
            epoch: 4,
            owner: (0..32u64).map(|s| 1 + s % 2).collect(),
            peers: vec![
                (1, "10.0.0.1:7000".into()),
                (2, "unix:/tmp/node2.sock".into()),
            ],
        },
        Msg::Expect { shards: vec![0, 5, 31] },
        Msg::Seal { shards: Vec::new() }, // pure barrier
        Msg::Seal { shards: vec![3] },
        Msg::Adopt {
            shards: vec![1, 2],
            records: vec![vec![0xAA; 33], Vec::new()],
        },
        Msg::Replay { samples: vec![sample(9, 120)] },
        Msg::Samples { samples: vec![sample(1, 0), sample(2, 1)] },
        Msg::Table { epoch: 3, owner: (0..32u64).map(|s| 1 + s % 2).collect() },
        Msg::Settle,
        Msg::Status,
        Msg::Ok,
        Msg::Denied { reason: "stale epoch 2 < 3".into() },
        Msg::Bundle { records: vec![b"opaque persist record".to_vec()] },
        Msg::HelloOk { node_id: 2, epoch: 3 },
        Msg::StatusText { text: "node 1 \u{2014} epoch 3".into() },
    ]
}

/// Hand-build a frame so individual header fields can be forged while
/// the frame check stays valid (mirrors `frame::encode`).
fn forge(type_id: u8, len_field: u32, payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.push(type_id);
    out.push(0); // flags
    out.extend_from_slice(&len_field.to_le_bytes());
    let check = crc32(payload) ^ crc32(&out[4..12]);
    out.extend_from_slice(&check.to_le_bytes());
    out.extend_from_slice(payload);
    out
}

#[test]
fn every_variant_roundtrips() {
    for msg in every_msg() {
        let wire = frame::encode(&msg);
        assert_eq!(
            frame::decode(&wire).unwrap(),
            msg,
            "{}: slice decode",
            msg.label()
        );
        let mut cur = Cursor::new(wire);
        assert_eq!(
            frame::read_msg(&mut cur).unwrap(),
            Some(msg.clone()),
            "{}: stream decode",
            msg.label()
        );
    }
}

#[test]
fn back_to_back_frames_stream_cleanly() {
    // A connection handler reads frames in sequence off one stream;
    // exhaustion of the stream is a clean disconnect.
    let mut wire = Vec::new();
    for msg in every_msg() {
        frame::write_msg(&mut wire, &msg).unwrap();
    }
    let mut cur = Cursor::new(wire);
    for msg in every_msg() {
        assert_eq!(frame::read_msg(&mut cur).unwrap(), Some(msg));
    }
    assert_eq!(frame::read_msg(&mut cur).unwrap(), None);
}

#[test]
fn every_single_bit_flip_is_rejected() {
    // Exhaustive, not sampled: every bit of every variant's frame. The
    // magic/version/length checks catch their own bytes, and the frame
    // check covers everything else INCLUDING the type and flags bytes —
    // a payload-only CRC would let a flipped type byte reinterpret the
    // frame as a different message.
    for msg in every_msg() {
        let good = frame::encode(&msg);
        for bit in 0..good.len() * 8 {
            let mut bad = good.clone();
            bad[bit / 8] ^= 1 << (bit % 8);
            assert!(
                frame::decode(&bad).is_err(),
                "{}: flipped bit {bit} still decoded",
                msg.label()
            );
        }
    }
}

#[test]
fn multi_bit_corruption_never_decodes_or_lies() {
    // Heavier damage may in principle collide the CRC; if a corrupt
    // frame decodes at all it must decode to the IDENTICAL message
    // (fixed seed: deterministic, no flaky collisions).
    let mut rng = SplitMix64::new(0x7ED2_F1A6);
    for msg in every_msg() {
        let good = frame::encode(&msg);
        for trial in 0..128 {
            let mut bad = good.clone();
            let flips = 2 + (rng.next_u64() % 63) as usize;
            for _ in 0..flips {
                let bit = rng.next_u64() as usize % (bad.len() * 8);
                bad[bit / 8] ^= 1 << (bit % 8);
            }
            if bad == good {
                continue; // flips cancelled out
            }
            match frame::decode(&bad) {
                Err(_) => {}
                Ok(m) => assert_eq!(
                    m,
                    msg,
                    "{} trial {trial}: corrupt frame decoded to a \
                     DIFFERENT message",
                    msg.label()
                ),
            }
        }
    }
}

#[test]
fn every_truncation_is_a_clean_error() {
    for msg in every_msg() {
        let good = frame::encode(&msg);
        for cut in 0..good.len() {
            assert!(
                frame::decode(&good[..cut]).is_err(),
                "{}: truncation to {cut}/{} bytes decoded",
                msg.label(),
                good.len()
            );
        }
    }
}

#[test]
fn trailing_garbage_is_a_clean_error() {
    for msg in [Msg::Settle, Msg::Hello { node_id: 1, epoch: 2 }] {
        let mut bad = frame::encode(&msg);
        bad.push(0x00);
        assert!(frame::decode(&bad).is_err(), "{}", msg.label());
    }
}

#[test]
fn oversized_length_prefix_is_rejected_before_allocating() {
    for len in [(MAX_PAYLOAD + 1) as u32, u32::MAX] {
        let bad = forge(9 /* Settle */, len, &[]);
        let err = frame::decode(&bad).unwrap_err();
        assert!(
            format!("{err}").contains("exceeds cap"),
            "want a length-cap error, got: {err}"
        );
        // The streaming reader must reject from the header alone: the
        // cursor holds only 16 bytes, so if read_msg had tried to
        // allocate-and-fill the payload the error would be a
        // mid-payload disconnect instead.
        let mut cur = Cursor::new(bad);
        let err = frame::read_msg(&mut cur).unwrap_err();
        assert!(
            format!("{err}").contains("exceeds cap"),
            "read_msg reached past the header: {err}"
        );
    }
}

#[test]
fn count_bomb_inside_payload_is_rejected() {
    // A valid frame whose payload claims 2^30-ish elements: the
    // bounds-checked reader must reject the count against the bytes
    // actually present instead of allocating element-count capacity.
    let bomb = 0x3FFF_FFFFu32.to_le_bytes();
    for type_id in [3u8, 4, 5, 6, 7, 0x42] {
        // Expect/Seal/Adopt/Replay/Samples/Bundle all lead with counts.
        let bad = forge(type_id, bomb.len() as u32, &bomb);
        assert!(
            frame::decode(&bad).is_err(),
            "type {type_id}: count bomb decoded"
        );
    }
    // JoinOk leads with an epoch word; its bombs sit one field in —
    // the owner count — so forge the epoch and then the bomb.
    let mut tail = 3u64.to_le_bytes().to_vec();
    tail.extend_from_slice(&bomb);
    let bad = forge(0x45, tail.len() as u32, &tail);
    assert!(frame::decode(&bad).is_err(), "JoinOk count bomb decoded");
    // Join's bomb is a string length claiming ~1 GiB of address.
    let mut tail = 7u64.to_le_bytes().to_vec();
    tail.extend_from_slice(&bomb);
    let bad = forge(11, tail.len() as u32, &tail);
    assert!(frame::decode(&bad).is_err(), "Join length bomb decoded");
}

#[test]
fn unknown_type_version_and_magic_are_clean_errors() {
    // Unknown type id with an otherwise perfect frame.
    assert!(frame::decode(&forge(0x7F, 0, &[])).is_err());
    // Wrong version, correct everything else.
    let mut bad = forge(9, 0, &[]);
    bad[4] = 0xFF;
    let check = crc32(&[]) ^ crc32(&bad[4..12]);
    bad[12..16].copy_from_slice(&check.to_le_bytes());
    let err = frame::decode(&bad).unwrap_err();
    assert!(format!("{err}").contains("version"), "{err}");
    // Garbage that never had a magic.
    let mut rng = SplitMix64::new(7);
    for len in [0usize, 1, 15, 16, 17, 64, 1024] {
        let garbage: Vec<u8> =
            (0..len).map(|_| rng.next_u64() as u8).collect();
        assert!(
            frame::decode(&garbage).is_err(),
            "{len} bytes of garbage decoded"
        );
    }
}

// ---- mid-stream disconnects over a real socket -------------------------

/// Server accepts one connection, writes `bytes`, closes. Returns what
/// the client's `read_msg` saw.
fn read_after_peer_sent(bytes: &[u8]) -> Result<Option<Msg>> {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let payload = bytes.to_vec();
    let server = thread::spawn(move || {
        let (mut s, _) = listener.accept().unwrap();
        s.write_all(&payload).unwrap();
        // drop(s): FIN after the partial frame.
    });
    let mut client = TcpStream::connect(addr).unwrap();
    let got = frame::read_msg(&mut client);
    server.join().unwrap();
    got
}

/// Client connects, writes `bytes`, closes. Returns what the server's
/// `read_msg` saw — the other direction of the same contract.
fn server_read_after_client_sent(bytes: &[u8]) -> Result<Option<Msg>> {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let payload = bytes.to_vec();
    let client = thread::spawn(move || {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(&payload).unwrap();
    });
    let (mut conn, _) = listener.accept().unwrap();
    let got = frame::read_msg(&mut conn);
    client.join().unwrap();
    got
}

#[test]
fn clean_eof_before_a_header_is_a_disconnect_not_an_error() {
    assert!(matches!(read_after_peer_sent(&[]), Ok(None)));
    assert!(matches!(server_read_after_client_sent(&[]), Ok(None)));
}

#[test]
fn whole_frames_cross_a_real_socket_in_both_directions() {
    let msg = Msg::Hello { node_id: 1, epoch: 2 };
    let wire = frame::encode(&msg);
    assert_eq!(read_after_peer_sent(&wire).unwrap(), Some(msg.clone()));
    assert_eq!(server_read_after_client_sent(&wire).unwrap(), Some(msg));
}

#[test]
fn eof_mid_header_or_mid_payload_is_an_error_both_directions() {
    let wire = frame::encode(&Msg::Hello { node_id: 1, epoch: 2 });
    assert_eq!(wire.len(), HEADER_LEN + 16);
    for cut in [1, 7, HEADER_LEN - 1, HEADER_LEN, HEADER_LEN + 5,
        wire.len() - 1]
    {
        assert!(
            read_after_peer_sent(&wire[..cut]).is_err(),
            "client read: peer died after {cut}/{} bytes",
            wire.len()
        );
        assert!(
            server_read_after_client_sent(&wire[..cut]).is_err(),
            "server read: peer died after {cut}/{} bytes",
            wire.len()
        );
    }
}

#[test]
fn cancellable_read_survives_timeouts_and_honors_stop() {
    use std::sync::atomic::{AtomicBool, Ordering};

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let client = thread::spawn(move || {
        let mut s = TcpStream::connect(addr).unwrap();
        // Hold the connection idle across several READ_TIMEOUT ticks,
        // then send two frames (one zero-payload) back to back.
        thread::sleep(READ_TIMEOUT * 3);
        frame::write_msg(&mut s, &Msg::Settle).unwrap();
        frame::write_msg(&mut s, &Msg::Hello { node_id: 4, epoch: 0 })
            .unwrap();
        // Keep the socket open until the server is done reading.
        thread::sleep(READ_TIMEOUT * 6);
    });
    let (conn, _) = listener.accept().unwrap();
    conn.set_read_timeout(Some(READ_TIMEOUT)).unwrap();
    let mut conn = conn;
    let stop = AtomicBool::new(false);
    // Timeout ticks while the peer is idle are absorbed, not errors —
    // and a zero-payload frame decodes without a zero-byte read being
    // mistaken for a disconnect.
    assert_eq!(
        frame::read_msg_cancellable(&mut conn, &stop).unwrap(),
        Some(Msg::Settle)
    );
    assert_eq!(
        frame::read_msg_cancellable(&mut conn, &stop).unwrap(),
        Some(Msg::Hello { node_id: 4, epoch: 0 })
    );
    // With the stop flag raised, an idle connection yields a prompt
    // clean exit instead of blocking forever.
    stop.store(true, Ordering::Release);
    assert_eq!(frame::read_msg_cancellable(&mut conn, &stop).unwrap(), None);
    client.join().unwrap();
}

#[test]
fn cancellable_read_reports_mid_frame_death() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let wire = frame::encode(&Msg::Hello { node_id: 1, epoch: 2 });
    let half = wire.len() / 2;
    let client = thread::spawn(move || {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(&wire[..half]).unwrap();
    });
    let (conn, _) = listener.accept().unwrap();
    conn.set_read_timeout(Some(READ_TIMEOUT)).unwrap();
    let mut conn = conn;
    let stop = std::sync::atomic::AtomicBool::new(false);
    assert!(frame::read_msg_cancellable(&mut conn, &stop).is_err());
    client.join().unwrap();
}

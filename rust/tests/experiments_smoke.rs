//! Smoke tests for the paper's experiments: every figure/table driver
//! must produce the paper's qualitative result (DESIGN.md §5 validation
//! bar). These run the same code paths as the examples, on smaller
//! budgets where possible.

use teda_fpga::damadics::{
    actuator1_schedule, evaluate_detection, ActuatorSim,
};
use teda_fpga::teda::TedaDetector;

/// Figs. 6–7: for every Table 2 fault item, ζ must cross 5/k inside the
/// fault window (detection), with a sane false-alarm budget outside.
#[test]
fn teda_detects_every_table2_fault() {
    for event in actuator1_schedule() {
        let sim = ActuatorSim::with_seed(2001);
        let trace = sim.generate_day(Some(&event));
        let mut det = TedaDetector::new(2, 3.0);
        let flags: Vec<bool> =
            trace.samples.iter().map(|s| det.step(s).outlier).collect();
        let report = evaluate_detection(&flags, &event, 1000);
        assert!(
            report.detected(),
            "item {} ({}) not detected",
            event.item,
            event.fault
        );
        let latency = report.latency.unwrap();
        assert!(
            latency < event.len(),
            "item {}: latency {} ≥ window {}",
            event.item,
            latency,
            event.len()
        );
        // The paper's plots show clean normal behaviour before the fault;
        // allow a modest false-alarm rate (process steps also excite ζ).
        assert!(
            report.false_alarm_rate() < 0.05,
            "item {}: false alarm rate {}",
            event.item,
            report.false_alarm_rate()
        );
    }
}

/// Healthy day: no fault window, and the overall flag rate stays small.
#[test]
fn healthy_day_low_flag_rate() {
    let sim = ActuatorSim::with_seed(2002);
    let trace = sim.generate_day(None);
    let mut det = TedaDetector::new(2, 3.0);
    let flags: Vec<bool> =
        trace.samples.iter().map(|s| det.step(s).outlier).collect();
    let after_warmup = &flags[1000..];
    let rate = after_warmup.iter().filter(|&&f| f).count() as f64
        / after_warmup.len() as f64;
    assert!(rate < 0.02, "healthy flag rate {rate}");
}

//! Single-TEDA vs fused-ensemble detection quality on the DAMADICS
//! fault schedule (Tables 1–2).
//!
//! ```bash
//! cargo run --release --example ensemble_fusion
//! cargo run --release --example ensemble_fusion -- \
//!     --members "teda+teda:m=2.5+msigma" --combiner majority
//! cargo run --release --example ensemble_fusion -- --item 7 --verbose
//! ```
//!
//! For every Table 2 fault item this driver replays the same simulated
//! actuator day through (a) the paper's single TEDA detector (m = 3)
//! and (b) an N-member fused ensemble, then prints one comparison row
//! each: detection, latency (samples after fault onset), and false
//! alarm rate outside the fault window. With `--verbose` it also dumps
//! the per-member vote balance so you can see *which* detector family
//! carried each decision.

use teda_fpga::config::{CombinerKind, EnsembleConfig};
use teda_fpga::damadics::{
    actuator1_schedule, evaluate_detection, schedule_item, ActuatorSim,
};
use teda_fpga::engine::Engine as _;
use teda_fpga::ensemble::EnsembleEngine;
use teda_fpga::stream::Sample;
use teda_fpga::teda::TedaDetector;

struct Args {
    item: Option<u32>,
    members: String,
    combiner: CombinerKind,
    m: f64,
    seed: u64,
    verbose: bool,
}

fn parse_args() -> Args {
    let mut args = Args {
        item: None,
        members: "teda:m=3+msigma:m=3+zscore:m=3,w=64".to_string(),
        combiner: CombinerKind::Majority,
        m: 3.0,
        seed: 2001,
        verbose: false,
    };
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut i = 0;
    while i < argv.len() {
        match argv[i].as_str() {
            "--item" => {
                args.item = Some(argv[i + 1].parse().expect("--item"));
                i += 2;
            }
            "--members" => {
                args.members = argv[i + 1].clone();
                i += 2;
            }
            "--combiner" => {
                args.combiner = argv[i + 1].parse().expect("--combiner");
                i += 2;
            }
            "--m" => {
                args.m = argv[i + 1].parse().expect("--m");
                i += 2;
            }
            "--seed" => {
                args.seed = argv[i + 1].parse().expect("--seed");
                i += 2;
            }
            "--verbose" => {
                args.verbose = true;
                i += 1;
            }
            other => panic!("unknown flag {other}"),
        }
    }
    args
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args = parse_args();
    let ecfg =
        EnsembleConfig::from_member_list(&args.members, args.combiner)?;
    let items: Vec<u32> = match args.item {
        Some(i) => vec![i],
        None => actuator1_schedule().iter().map(|e| e.item).collect(),
    };
    println!(
        "ensemble: [{}] via {}\n",
        ecfg.labels().join(", "),
        ecfg.combiner
    );
    println!(
        "item | fault | single: det lat    far     | fused: det lat    far"
    );
    println!(
        "-----|-------|---------------------------|-----------------------"
    );
    let mut fused_detected = 0usize;
    let mut single_detected = 0usize;
    for item in &items {
        let (s, f) = run_item(*item, &args, &ecfg)?;
        single_detected += s as usize;
        fused_detected += f as usize;
    }
    println!(
        "\ndetected {}/{} single vs {}/{} fused",
        single_detected,
        items.len(),
        fused_detected,
        items.len()
    );
    Ok(())
}

/// Returns (single detected, fused detected) for one Table 2 item.
fn run_item(
    item: u32,
    args: &Args,
    ecfg: &EnsembleConfig,
) -> Result<(bool, bool), Box<dyn std::error::Error>> {
    let event = schedule_item(item).ok_or("unknown Table 2 item")?;
    let trace =
        ActuatorSim::with_seed(args.seed).generate_day(Some(&event));

    // (a) Single TEDA, the paper's configuration.
    let mut det = TedaDetector::new(2, args.m);
    let single: Vec<bool> =
        trace.samples.iter().map(|s| det.step(s).outlier).collect();
    let single_report = evaluate_detection(&single, &event, 1000);

    // (b) Fused ensemble over the identical day.
    let mut eng =
        EnsembleEngine::new(ecfg, 2)?.with_breakdown(args.verbose);
    let mut fused = vec![false; trace.samples.len()];
    for (seq, values) in trace.samples.iter().enumerate() {
        let sample = Sample {
            stream_id: 0,
            seq: seq as u64,
            values: values.clone(),
        };
        for v in eng.ingest(&sample)? {
            fused[v.seq as usize] = v.outlier;
        }
    }
    for v in eng.flush()? {
        fused[v.seq as usize] = v.outlier;
    }
    let fused_report = evaluate_detection(&fused, &event, 1000);

    println!(
        "  {}  | {:<5} | {:<5} {:>6} {:.5} | {:<5} {:>6} {:.5}",
        item,
        event.fault.to_string(),
        single_report.detected(),
        single_report
            .latency
            .map(|l| l.to_string())
            .unwrap_or_else(|| "-".into()),
        single_report.false_alarm_rate(),
        fused_report.detected(),
        fused_report
            .latency
            .map(|l| l.to_string())
            .unwrap_or_else(|| "-".into()),
        fused_report.false_alarm_rate(),
    );

    if args.verbose {
        // Vote balance inside the fault window: who carried the call?
        let mut per_member_hits =
            vec![0u64; eng.n_members()];
        let labels = eng.member_labels();
        for b in eng.take_breakdowns() {
            let seq = b.seq as usize;
            if seq >= event.start && seq <= event.end {
                for (i, (_, flag, _)) in b.votes.iter().enumerate() {
                    if *flag {
                        per_member_hits[i] += 1;
                    }
                }
            }
        }
        for (label, hits) in labels.iter().zip(&per_member_hits) {
            println!("         {label:<20} {hits} window hits");
        }
    }
    Ok((single_report.detected(), fused_report.detected()))
}

//! Quickstart: detect anomalies in a stream with three lines of setup.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Walks the public API bottom-up: the bare detector, the baselines, the
//! hardware (RTL) pipeline, and a quick look at what the synthesized
//! design would cost on the paper's FPGA.

use teda_fpga::baselines::{AnomalyDetector, MSigmaDetector, SlidingZScore};
use teda_fpga::rtl::TedaRtl;
use teda_fpga::synth::{OccupationReport, PipelineTiming, Virtex6};
use teda_fpga::teda::TedaDetector;
use teda_fpga::util::prng::SplitMix64;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // --- 1. The TEDA detector (Algorithm 1 of the paper) --------------
    // N=2 features, Chebyshev multiplier m=3 (the paper's setting).
    let mut det = TedaDetector::new(2, 3.0);

    // A well-behaved sensor stream... (TEDA may legitimately flag the
    // occasional >3σ tail draw — that's the Chebyshev bound working)
    let mut rng = SplitMix64::new(7);
    let mut tail_flags = 0;
    for _ in 0..500 {
        let x = [rng.normal_with(1.0, 0.05), rng.normal_with(0.5, 0.02)];
        if det.step(&x).outlier {
            tail_flags += 1;
        }
    }
    assert!(tail_flags < 15, "quiet stream too noisy: {tail_flags}");
    // ...until something breaks:
    let v = det.step(&[2.5, -0.7]);
    println!(
        "sample k={}: zeta={:.4} threshold={:.6} outlier={}",
        v.k, v.zeta, v.threshold, v.outlier
    );
    assert!(v.outlier);

    // --- 2. Compare with the traditional baselines --------------------
    let mut msigma = MSigmaDetector::new(2, 3.0);
    let mut zscore = SlidingZScore::new(2, 3.0, 128);
    let mut rng = SplitMix64::new(7);
    for _ in 0..500 {
        let x = [rng.normal_with(1.0, 0.05), rng.normal_with(0.5, 0.02)];
        msigma.step(&x);
        zscore.step(&x);
    }
    println!(
        "baselines on the same spike: m-sigma={} sliding-z={}",
        msigma.step(&[2.5, -0.7]),
        zscore.step(&[2.5, -0.7])
    );

    // --- 3. The same computation, as the paper's hardware -------------
    let mut rtl = TedaRtl::new(2, 3.0)?;
    let mut rng = SplitMix64::new(7);
    let samples: Vec<Vec<f32>> = (0..500)
        .map(|_| {
            vec![
                rng.normal_with(1.0, 0.05) as f32,
                rng.normal_with(0.5, 0.02) as f32,
            ]
        })
        .collect();
    let verdicts = rtl.run(&samples)?;
    println!(
        "RTL pipeline classified {} samples (pipeline latency 2 cycles)",
        verdicts.len()
    );

    // --- 4. What would this cost on the paper's Virtex-6? -------------
    let occ = OccupationReport::analyze(rtl.netlist(), Virtex6::xc6vlx240t());
    let t = PipelineTiming::analyze(rtl.netlist());
    println!(
        "synthesized: {} DSP multipliers, {} LUTs, t_c={} ns → {:.1} MSPS",
        occ.multipliers,
        occ.luts,
        t.critical_ns,
        t.throughput_sps / 1e6
    );
    println!("quickstart OK");
    Ok(())
}

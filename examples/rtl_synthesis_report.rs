//! Tables 3 & 4 reproduction: synthesis estimate of the TEDA RTL design.
//!
//! ```bash
//! cargo run --release --example rtl_synthesis_report              # N=2 (paper)
//! cargo run --release --example rtl_synthesis_report -- --sweep   # N scaling study
//! cargo run --release --example rtl_synthesis_report -- --netlist # dump instances
//! ```
//!
//! Analyzes the same netlist the simulator executes: component
//! inventory → Virtex-6 occupation (Table 3), static timing → critical
//! path and throughput (Table 4, Eqs. 7–9).

use teda_fpga::rtl::TedaRtl;
use teda_fpga::synth::{
    critical_path, OccupationReport, PipelineTiming, Virtex6,
};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let sweep = argv.iter().any(|a| a == "--sweep");
    let netlist = argv.iter().any(|a| a == "--netlist");

    // ---------------- the paper's configuration: N = 2 ----------------
    let rtl = TedaRtl::new(2, 3.0)?;
    let occ = OccupationReport::analyze(rtl.netlist(), Virtex6::xc6vlx240t());
    let timing = PipelineTiming::analyze(rtl.netlist());

    println!("== TEDA RTL synthesis estimate — N=2 (the paper's setup) ==\n");
    println!("{}", occ.render_table3());
    println!(
        "  ({} FP mult cores × 3 DSP48E1, {} divider cores, {} add/sub cores)\n",
        occ.mult_cores, occ.div_cores, occ.addsub_cores
    );
    println!("{}", timing.render_table4());
    let path = critical_path(rtl.netlist());
    println!("critical path ({} ns): {}", path.critical_ns, path.path.join(" → "));
    println!("\npaper reference: 27 mult (3%), 414 reg (<1%), 11567 LUT (7%);");
    println!("                 t_c=138 ns, d=414 ns, 7.2 MSPS\n");

    if sweep {
        // ------------- the scaling study the paper omits --------------
        println!("== N-feature scaling (model extrapolation) ==\n");
        println!("  N | mult cores | DSP | LUT    | FF   | t_c (ns) | MSPS");
        println!("----|------------|-----|--------|------|----------|------");
        for n in [1usize, 2, 3, 4, 6, 8, 12, 16] {
            let rtl = TedaRtl::new(n, 3.0)?;
            let occ =
                OccupationReport::analyze(rtl.netlist(), Virtex6::xc6vlx240t());
            let t = PipelineTiming::analyze(rtl.netlist());
            println!(
                " {n:>2} | {:>10} | {:>3} | {:>6} | {:>4} | {:>8.0} | {:>4.1}",
                occ.mult_cores,
                occ.multipliers,
                occ.luts,
                occ.registers,
                t.critical_ns,
                t.throughput_sps / 1e6
            );
        }
        println!(
            "\n(beyond N≈3 the VSUM1 adder chain of the VARIANCE stage\n\
             overtakes the MEAN stage divider path and t_c grows linearly;\n\
             a balanced adder tree would restore it — see DESIGN.md §Perf)"
        );
    }

    if netlist {
        println!("\n== netlist (N=2) ==\n{}", rtl.netlist().dump());
    }
    Ok(())
}

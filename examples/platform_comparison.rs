//! Table 5 reproduction: platform comparison — modeled FPGA vs measured
//! software implementations.
//!
//! ```bash
//! cargo run --release --example platform_comparison              # rust + xla rows
//! cargo run --release --example platform_comparison -- --python  # + naive python row
//! ```
//!
//! The paper compares its FPGA (138 ns/sample) against Python on three
//! software platforms (435 ms, 39.2 ms, 23.1 ms per sample) and reports
//! speedups of 3 000 000× / 280 000× / 167 000×. We cannot re-run Colab
//! or a Tesla K80, so the reproduction keeps the comparison *structure*
//! (modeled FPGA vs per-sample times measured on THIS host) and checks
//! the paper's qualitative claim: the FPGA wins by orders of magnitude
//! against interpreted software, and remains ahead of compiled software.
//!
//! Rows produced:
//!   FPGA (timing model)        — t_c from the synthesized netlist
//!   Rust  (software TEDA)      — measured, this host
//!   Rust  (RTL simulator)      — measured, cycle-accurate simulation cost
//!   XLA   (batched, PJRT CPU)  — measured, amortized per sample
//!   Python (naive, this host)  — measured via `python3` when --python

use std::time::Instant;

use teda_fpga::rtl::TedaRtl;
use teda_fpga::runtime::XlaRuntime;
use teda_fpga::synth::PipelineTiming;
use teda_fpga::teda::TedaDetector;
use teda_fpga::util::prng::SplitMix64;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let want_python = std::env::args().any(|a| a == "--python");
    let mut rows: Vec<(String, f64)> = Vec::new(); // (platform, ns/sample)

    // ---- FPGA (timing model of the paper's architecture) -------------
    let rtl = TedaRtl::new(2, 3.0)?;
    let fpga_ns = PipelineTiming::analyze(rtl.netlist()).teda_time_ns;
    rows.push(("This work's architecture on FPGA (modeled)".into(), fpga_ns));

    // ---- Rust software TEDA ------------------------------------------
    let mut rng = SplitMix64::new(3);
    let samples: Vec<Vec<f64>> = (0..1_000_000)
        .map(|_| vec![rng.next_f64(), rng.next_f64()])
        .collect();
    let mut det = TedaDetector::new(2, 3.0);
    // Warmup.
    for s in samples.iter().take(10_000) {
        std::hint::black_box(det.step(s));
    }
    let t0 = Instant::now();
    for s in &samples {
        std::hint::black_box(det.step(s));
    }
    let rust_ns = t0.elapsed().as_nanos() as f64 / samples.len() as f64;
    rows.push(("Rust software TEDA (this host)".into(), rust_ns));

    // ---- Rust RTL simulator (cost of *simulating* the hardware) ------
    let mut rtl = TedaRtl::new(2, 3.0)?;
    let s32: Vec<Vec<f32>> = samples[..100_000]
        .iter()
        .map(|s| s.iter().map(|&v| v as f32).collect())
        .collect();
    let t0 = Instant::now();
    for s in &s32 {
        std::hint::black_box(rtl.clock(s)?);
    }
    let rtlsim_ns = t0.elapsed().as_nanos() as f64 / s32.len() as f64;
    rows.push(("Rust cycle-accurate RTL simulator (this host)".into(), rtlsim_ns));

    // ---- XLA batched (PJRT CPU) --------------------------------------
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if std::path::Path::new(dir).join("manifest.json").exists() {
        let rt = XlaRuntime::new(dir)?;
        let spec = rt.manifest().select(2, 1024).unwrap().clone();
        let exe = rt.load(&spec.name)?;
        let (s, t, n) = (spec.s, spec.t, spec.n);
        let mut rng = SplitMix64::new(5);
        let mu = vec![0f32; s * n];
        let var = vec![0f32; s];
        let k = vec![1f32; s];
        let x: Vec<f32> =
            (0..s * t * n).map(|_| rng.next_f64() as f32).collect();
        for _ in 0..5 {
            exe.run_f32(&[&mu, &var, &k, &x])?; // warmup
        }
        let iters = 200;
        let t0 = Instant::now();
        for _ in 0..iters {
            std::hint::black_box(exe.run_f32(&[&mu, &var, &k, &x])?);
        }
        let per_sample =
            t0.elapsed().as_nanos() as f64 / (iters * s * t) as f64;
        rows.push((
            format!("XLA/Pallas artifact {} (PJRT CPU, batched)", spec.name),
            per_sample,
        ));
    } else {
        eprintln!("(artifacts missing — skipping XLA row)");
    }

    // ---- Naive Python (the paper's software baseline) -----------------
    if want_python {
        match python_per_sample_ns() {
            Ok(ns) => {
                rows.push(("Python recursive TEDA (this host)".into(), ns))
            }
            Err(e) => eprintln!("(python row skipped: {e})"),
        }
        // The paper's 435 ms/sample Colab baseline is only reachable by a
        // NON-recursive implementation that rescans history each step —
        // the "traditional method" TEDA §3 argues against. Measure it at
        // the paper's operating point (k ≈ 58 800, where Fig. 6 sits).
        match python_nonrecursive_ns() {
            Ok(ns) => rows.push((
                "Python non-recursive (rescan history, k=58800)".into(),
                ns,
            )),
            Err(e) => eprintln!("(python non-recursive row skipped: {e})"),
        }
    }

    // ---- Render Table 5 ----------------------------------------------
    println!("\nTable 5: Software implementations comparison (reproduced)\n");
    println!("| {:<52} | {:>14} | {:>12} |", "Platform", "Time/sample", "Speedup");
    println!("|{:-<54}|{:-<16}|{:-<14}|", "", "", "");
    for (name, ns) in &rows {
        let speedup = ns / fpga_ns;
        let speedup_str = if (*ns - fpga_ns).abs() < 1e-9 {
            "—".to_string()
        } else if speedup >= 100.0 {
            format!("{speedup:.0}×")
        } else {
            format!("{speedup:.2}×")
        };
        println!(
            "| {:<52} | {:>14} | {:>12} |",
            name,
            fmt_time(*ns),
            speedup_str
        );
    }
    println!(
        "\npaper's published row set: FPGA 138 ns; Python/Colab 435 ms \
         (3,000,000×); Colab+K80 39.2 ms (280,000×); local 940MX 23.1 ms \
         (167,000×)."
    );
    println!(
        "validation bar: FPGA ≫ interpreted Python by ≥10⁴× and ahead of \
         every measured software row — see EXPERIMENTS.md."
    );
    Ok(())
}

fn fmt_time(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else {
        format!("{:.2} ms", ns / 1e6)
    }
}

/// Time a naive (pure-interpreter, per-sample loop) Python TEDA — the
/// equivalent of the paper's "Python (Colab without GPU)" row.
fn python_per_sample_ns() -> Result<f64, Box<dyn std::error::Error>> {
    let script = r#"
import time
def run(n):
    mu1=mu2=0.0; var=0.0; k=0
    import random
    random.seed(3)
    t0=time.perf_counter()
    for _ in range(n):
        x1=random.random(); x2=random.random()
        k+=1
        if k==1:
            mu1,mu2,var=x1,x2,0.0; continue
        r=(k-1)/k; ik=1.0/k
        mu1=mu1*r+x1*ik; mu2=mu2*r+x2*ik
        d1=x1-mu1; d2=x2-mu2; d2sum=d1*d1+d2*d2
        var=var*r+d2sum*ik
        ecc=ik+(d2sum/(var*k) if var>0 else 0.0)
        zeta=ecc/2.0
        out=zeta>5.0/k
    return (time.perf_counter()-t0)/n*1e9
run(20000)  # warmup
print(run(200000))
"#;
    let out = std::process::Command::new("python3").arg("-c").arg(script).output()?;
    if !out.status.success() {
        return Err(String::from_utf8_lossy(&out.stderr).into());
    }
    Ok(String::from_utf8(out.stdout)?.trim().parse::<f64>()?)
}

/// The "traditional" non-recursive formulation: each step recomputes
/// mean/variance/eccentricity by rescanning ALL history (pure-python
/// loops). At the paper's Fig. 6 operating point (k ≈ 58 800) one step
/// costs O(k) — this is the per-sample regime the paper's 435 ms Colab
/// row lives in (times a Colab-vs-2026-host constant).
fn python_nonrecursive_ns() -> Result<f64, Box<dyn std::error::Error>> {
    let script = r#"
import time, random
random.seed(3)
K = 58800
hist = [(random.random(), random.random()) for _ in range(K)]
def step(x1, x2):
    k = len(hist) + 1
    s1 = s2 = 0.0
    for (a, b) in hist:
        s1 += a; s2 += b
    mu1 = (s1 + x1) / k; mu2 = (s2 + x2) / k
    var = 0.0
    for (a, b) in hist:
        var += (a - mu1) ** 2 + (b - mu2) ** 2
    var = (var + (x1 - mu1) ** 2 + (x2 - mu2) ** 2) / k
    d2 = (x1 - mu1) ** 2 + (x2 - mu2) ** 2
    ecc = 1.0 / k + (d2 / (var * k) if var > 0 else 0.0)
    return ecc / 2.0 > 5.0 / k
step(0.5, 0.5)  # warmup
n = 20
t0 = time.perf_counter()
for i in range(n):
    step(0.1 * i, 0.5)
print((time.perf_counter() - t0) / n * 1e9)
"#;
    let out = std::process::Command::new("python3").arg("-c").arg(script).output()?;
    if !out.status.success() {
        return Err(String::from_utf8_lossy(&out.stderr).into());
    }
    Ok(String::from_utf8(out.stdout)?.trim().parse::<f64>()?)
}

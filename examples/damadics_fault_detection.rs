//! Figures 6 & 7 reproduction: DAMADICS fault detection with TEDA.
//!
//! ```bash
//! cargo run --release --example damadics_fault_detection -- --item 1 --out out/fig6
//! cargo run --release --example damadics_fault_detection -- --item 7 --out out/fig7
//! ```
//!
//! For the requested Table 2 fault item this driver emits the two CSV
//! series the paper plots:
//!
//! - `<out>_inputs.csv`  — the input vector x_k = [x1, x2]   (Fig a)
//! - `<out>_zeta.csv`    — normalized eccentricity ζ_k and the 5/k
//!   threshold (m = 3)                                        (Fig b)
//!
//! and prints the detection summary (fault window, first crossing,
//! latency, false alarms). Running without --item reproduces ALL seven
//! Table 2 items and prints one summary row each.

use std::io::Write as _;

use teda_fpga::damadics::{
    actuator1_schedule, evaluate_detection, schedule_item, ActuatorSim,
};
use teda_fpga::rtl::TedaRtl;
use teda_fpga::teda::TedaDetector;

struct Args {
    item: Option<u32>,
    out: Option<String>,
    seed: u64,
    m: f64,
    engine: String,
}

fn parse_args() -> Args {
    let mut args = Args {
        item: None,
        out: None,
        seed: 2001,
        m: 3.0,
        engine: "software".into(),
    };
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut i = 0;
    while i < argv.len() {
        match argv[i].as_str() {
            "--item" => {
                args.item = Some(argv[i + 1].parse().expect("--item"));
                i += 2;
            }
            "--out" => {
                args.out = Some(argv[i + 1].clone());
                i += 2;
            }
            "--seed" => {
                args.seed = argv[i + 1].parse().expect("--seed");
                i += 2;
            }
            "--m" => {
                args.m = argv[i + 1].parse().expect("--m");
                i += 2;
            }
            "--engine" => {
                args.engine = argv[i + 1].clone();
                i += 2;
            }
            other => panic!("unknown flag {other}"),
        }
    }
    args
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args = parse_args();
    let items: Vec<u32> = match args.item {
        Some(i) => vec![i],
        None => actuator1_schedule().iter().map(|e| e.item).collect(),
    };
    println!(
        "item | fault | window          | detected | latency | hits    | false-alarm rate"
    );
    println!(
        "-----|-------|-----------------|----------|---------|---------|-----------------"
    );
    for item in items {
        run_item(item, &args)?;
    }
    Ok(())
}

fn run_item(item: u32, args: &Args) -> Result<(), Box<dyn std::error::Error>> {
    let event = schedule_item(item).ok_or("unknown Table 2 item")?;
    let sim = ActuatorSim::with_seed(args.seed);
    let trace = sim.generate_day(Some(&event));

    // Classify the full day, collecting the ζ series.
    let (zetas, thresholds, flags): (Vec<f64>, Vec<f64>, Vec<bool>) =
        match args.engine.as_str() {
            "software" => {
                let mut det = TedaDetector::new(2, args.m);
                let mut z = Vec::new();
                let mut t = Vec::new();
                let mut f = Vec::new();
                for s in &trace.samples {
                    let v = det.step(s);
                    z.push(v.zeta);
                    t.push(v.threshold);
                    f.push(v.outlier);
                }
                (z, t, f)
            }
            "rtl" => {
                let mut rtl = TedaRtl::new(2, args.m as f32)?;
                let s32: Vec<Vec<f32>> = trace
                    .samples
                    .iter()
                    .map(|s| s.iter().map(|&v| v as f32).collect())
                    .collect();
                let verdicts = rtl.run(&s32)?;
                (
                    verdicts.iter().map(|v| v.zeta as f64).collect(),
                    verdicts.iter().map(|v| v.threshold as f64).collect(),
                    verdicts.iter().map(|v| v.outlier).collect(),
                )
            }
            other => return Err(format!("unknown engine {other}").into()),
        };

    let report = evaluate_detection(&flags, &event, 1000);
    println!(
        "{:>4} | {:>5} | {:>6}-{:<8} | {:>8} | {:>7} | {:>3}/{:<3} | {:.5}",
        event.item,
        event.fault.to_string(),
        event.start,
        event.end,
        report.detected(),
        report
            .latency
            .map(|l| l.to_string())
            .unwrap_or_else(|| "-".into()),
        report.hits_in_window,
        report.window_len,
        report.false_alarm_rate(),
    );

    // CSV output for plotting (window ±2000 samples, like the paper's
    // zoomed panels).
    if let Some(out) = &args.out {
        let lo = event.start.saturating_sub(2000);
        let hi = (event.end + 2000).min(trace.len());
        if let Some(parent) = std::path::Path::new(out).parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f_in =
            std::io::BufWriter::new(std::fs::File::create(format!("{out}_inputs.csv"))?);
        writeln!(f_in, "k,x1,x2,label")?;
        for k in lo..hi {
            writeln!(
                f_in,
                "{k},{:.6},{:.6},{}",
                trace.samples[k][0],
                trace.samples[k][1],
                trace.labels[k] as u8
            )?;
        }
        let mut f_z =
            std::io::BufWriter::new(std::fs::File::create(format!("{out}_zeta.csv"))?);
        writeln!(f_z, "k,zeta,threshold,outlier")?;
        for k in lo..hi {
            writeln!(
                f_z,
                "{k},{:.8},{:.8},{}",
                zetas[k],
                thresholds[k],
                flags[k] as u8
            )?;
        }
        println!("   wrote {out}_inputs.csv and {out}_zeta.csv ({lo}..{hi})");
    }
    Ok(())
}

//! End-to-end driver (the repo's headline validation run): the full
//! three-layer system on a real workload.
//!
//! ```bash
//! cargo run --release --example streaming_service                 # xla engine
//! cargo run --release --example streaming_service -- --engine software
//! cargo run --release --example streaming_service -- --streams 64 --samples 20000
//! ```
//!
//! Pipeline exercised: DAMADICS actuator traces (L3 substrate) →
//! bounded ingress queues → router → worker threads → the AOT-compiled
//! JAX/Pallas TEDA kernel via PJRT (L1+L2) → verdicts + latency
//! histograms. Python is NOT involved at runtime — only the artifacts
//! built once by `make artifacts` are loaded.
//!
//! Prints the serving metrics (throughput, p50/p95/p99 latency,
//! backpressure) plus detection quality on the faulty streams, and
//! cross-checks every verdict against the software oracle.

use std::time::Instant;

use teda_fpga::config::{EngineKind, ServiceConfig};
use teda_fpga::coordinator::Service;
use teda_fpga::damadics::{
    actuator1_schedule, evaluate_detection, ActuatorConfig, ActuatorSim,
};
use teda_fpga::stream::{ReplaySource, Sample, StreamSource};
use teda_fpga::teda::TedaDetector;

struct Args {
    engine: EngineKind,
    workers: usize,
    streams: u64,
    samples: usize,
}

fn parse_args() -> Args {
    let mut args = Args {
        engine: EngineKind::Xla,
        workers: 2,
        streams: 16,
        samples: 10_000,
    };
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut i = 0;
    while i < argv.len() {
        match argv[i].as_str() {
            "--engine" => {
                args.engine = argv[i + 1].parse().expect("--engine");
                i += 2;
            }
            "--workers" => {
                args.workers = argv[i + 1].parse().expect("--workers");
                i += 2;
            }
            "--streams" => {
                args.streams = argv[i + 1].parse().expect("--streams");
                i += 2;
            }
            "--samples" => {
                args.samples = argv[i + 1].parse().expect("--samples");
                i += 2;
            }
            other => panic!("unknown flag {other}"),
        }
    }
    args
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args = parse_args();
    let artifact_dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if args.engine == EngineKind::Xla
        && !std::path::Path::new(artifact_dir).join("manifest.json").exists()
    {
        return Err("artifacts missing — run `make artifacts` first".into());
    }

    let cfg = ServiceConfig {
        engine: args.engine,
        workers: args.workers,
        n_features: 2,
        queue_capacity: 512,
        artifact_dir: artifact_dir.into(),
        ..Default::default()
    };
    println!(
        "streaming_service: engine={} workers={} streams={} samples/stream={}",
        cfg.engine, cfg.workers, args.streams, args.samples
    );

    // Workload: DAMADICS actuator days. Every 4th stream gets a Table 2
    // fault injected (cycled), scaled into the replayed window.
    let schedule = actuator1_schedule();
    let mut sources = Vec::new();
    let mut faulty: Vec<(u64, teda_fpga::damadics::FaultEvent)> = Vec::new();
    for sid in 0..args.streams {
        let mut acfg = ActuatorConfig::default();
        acfg.samples = args.samples;
        let event = if sid % 4 == 0 {
            let mut e = schedule[(sid / 4) as usize % schedule.len()].clone();
            // Rescale the fault window into this trace length.
            let len = (e.len()).min(args.samples / 8).max(16);
            e.start = args.samples / 2;
            e.end = e.start + len - 1;
            Some(e)
        } else {
            None
        };
        let sim = ActuatorSim::new(9000 + sid, acfg);
        let trace = sim.generate_day(event.as_ref());
        if let Some(e) = event {
            faulty.push((sid, e));
        }
        sources.push(ReplaySource::new(sid, trace));
    }

    // Serve.
    let t0 = Instant::now();
    let svc = Service::start(cfg)?;
    let started = Instant::now();
    loop {
        // One burst per round across all sources (submit_batch keeps
        // channel synchronization off the per-sample path).
        let mut round = Vec::with_capacity(sources.len());
        for src in &mut sources {
            if let Some(s) = src.next_sample() {
                round.push(s);
            }
        }
        if round.is_empty() {
            break;
        }
        svc.submit_batch(round)?;
    }
    let submitted = Instant::now();
    let metrics = svc.metrics();
    let out = svc.finish()?;
    let done = Instant::now();

    let total = args.streams as usize * args.samples;
    assert_eq!(out.len(), total, "every sample must be classified");

    // Verdict cross-check against the oracle (sampled streams).
    let mut mismatches = 0usize;
    for &(sid, _) in faulty.iter().take(2) {
        let mut acfg = ActuatorConfig::default();
        acfg.samples = args.samples;
        let event = faulty.iter().find(|(s, _)| *s == sid).map(|(_, e)| e);
        let trace =
            ActuatorSim::new(9000 + sid, acfg).generate_day(event);
        let mut det = TedaDetector::new(2, 3.0);
        let oracle: Vec<bool> =
            trace.samples.iter().map(|s| det.step(s).outlier).collect();
        for c in out.iter().filter(|c| c.verdict.stream_id == sid) {
            if c.verdict.k > 1
                && c.verdict.outlier != oracle[c.verdict.seq as usize]
            {
                mismatches += 1;
            }
        }
    }

    // Detection quality on the faulty streams.
    println!("\nfault detection on faulty streams:");
    let mut detected = 0;
    for (sid, event) in &faulty {
        let mut flags = vec![false; args.samples];
        for c in out.iter().filter(|c| c.verdict.stream_id == *sid) {
            flags[c.verdict.seq as usize] = c.verdict.outlier;
        }
        let rep = evaluate_detection(&flags, event, 500);
        if rep.detected() {
            detected += 1;
        }
        println!(
            "  stream {sid:>3} {}: detected={} latency={:?} far={:.5}",
            event.fault,
            rep.detected(),
            rep.latency,
            rep.false_alarm_rate()
        );
    }

    println!("\n{}", metrics.render());
    let wall = done.duration_since(t0).as_secs_f64();
    println!(
        "headline: {} samples in {:.3}s wall ({:.0} samples/s end-to-end; \
         submit {:.3}s, drain {:.3}s, startup {:.3}s)",
        total,
        wall,
        total as f64 / done.duration_since(started).as_secs_f64(),
        submitted.duration_since(started).as_secs_f64(),
        done.duration_since(submitted).as_secs_f64(),
        started.duration_since(t0).as_secs_f64(),
    );
    println!(
        "oracle cross-check: {mismatches} flag mismatches on sampled streams \
         (f32-vs-f64 threshold edges only)"
    );
    println!(
        "faults detected: {detected}/{} faulty streams",
        faulty.len()
    );
    if detected < faulty.len() {
        return Err("not all injected faults were detected".into());
    }
    println!("streaming_service OK");
    Ok(())
}
